//! Offline stub of the `xla` (xla_extension) PJRT binding.
//!
//! The offline image does not vendor the native XLA runtime, so this
//! crate provides just enough of the binding's surface for the `pjrt`
//! feature of `gqsa` to compile. Every operation that would touch the
//! runtime returns an error at runtime. To actually execute AOT
//! artifacts, point the `xla` path dependency in `rust/Cargo.toml` at a
//! real xla_extension binding with the same API.

/// Error type; call sites format it with `{:?}`.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable<T>() -> Result<T, XlaError> {
    Err(XlaError(
        "xla runtime not vendored in this image (offline stub — see rust/xla_stub)".to_string(),
    ))
}

/// Element types the artifact loader maps tensor dtypes onto.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    S8,
    S64,
    U8,
    U16,
}

/// Scalar types accepted by `Literal::scalar` / `Literal::to_vec`.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Host-side tensor value.
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal { _private: () }
    }

    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal, XlaError> {
        unavailable()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        unavailable()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        unavailable()
    }
}

/// Parsed HLO module (text form).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        unavailable()
    }
}

/// A computation ready for compilation.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-resident output buffer.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable()
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable()
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everything_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[1], &[0; 4])
            .is_err());
        let lit = Literal::scalar(0f32);
        assert!(lit.to_vec::<f32>().is_err());
    }
}
