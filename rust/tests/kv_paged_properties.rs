//! Property tests for the paged, group-quantized KV cache:
//!
//! * paged-f32 attention is BIT-EXACT with the slab layout for random
//!   shapes and sequence lengths straddling block boundaries,
//! * Q8/Q4 KV keeps logits finite and close (per-group Eq. 1-3 bound
//!   at the vector level is asserted in model/kv_cache.rs unit tests),
//! * the block pool never leaks or double-frees across 1k simulated
//!   request lifecycles, and recycled blocks are poisoned so stale
//!   data cannot leak between requests,
//! * speculative rollback (commit floor -> overshoot -> truncate) on
//!   one sequence never mutates sealed shared-prefix blocks another
//!   sequence adopted — even when divergence truncates INTO the
//!   shared region (the CoW path).

use std::sync::Arc;

use gqsa::model::config::demo_config;
use gqsa::model::kv_cache::blocks_for;
use gqsa::model::transformer::random_fp;
use gqsa::model::{
    KvBlockPool, KvCache, KvDtype, ModelConfig, Scratch, Transformer, KV_BLOCK,
};
use gqsa::prefix::PrefixTree;
use gqsa::util::XorShift;

fn small_cfg(d_model: usize, n_layers: usize, n_heads: usize) -> ModelConfig {
    let mut cfg = demo_config();
    cfg.d_model = d_model;
    cfg.n_layers = n_layers;
    cfg.n_heads = n_heads;
    cfg.d_ff = d_model + d_model / 2;
    cfg.vocab = 64;
    cfg.max_seq = 8 * KV_BLOCK;
    cfg
}

#[test]
fn paged_f32_decode_bit_exact_vs_slab_across_shapes_and_lengths() {
    // shapes x lengths chosen to straddle block boundaries: one block
    // exactly, mid-block, boundary +/- 1, several blocks
    let lengths = [
        1usize,
        KV_BLOCK - 1,
        KV_BLOCK,
        KV_BLOCK + 1,
        2 * KV_BLOCK,
        3 * KV_BLOCK + 5,
    ];
    for (seed, (d, l, h)) in [(64usize, 2usize, 2usize), (48, 1, 4), (32, 3, 2)]
        .into_iter()
        .enumerate()
    {
        let cfg = small_cfg(d, l, h);
        let fp = random_fp(&cfg, 100 + seed as u64);
        let model = Transformer::from_fp(&fp).unwrap();
        let cap = 4 * KV_BLOCK + 8;
        for &n in &lengths {
            let mut rng = XorShift::new(seed as u64 * 31 + n as u64);
            let tokens: Vec<u32> = (0..n).map(|_| rng.below(60) as u32).collect();

            let mut kv_slab = KvCache::new(cfg.n_layers, cfg.n_heads, cfg.head_dim(), cap);
            let mut s_slab = Scratch::new(&cfg);
            let pool =
                KvBlockPool::new(cfg.n_heads, cfg.head_dim(), KvDtype::F32, cfg.n_layers * 8);
            let mut kv_paged = KvCache::paged(cfg.n_layers, &pool, cap);
            let mut s_paged = Scratch::new(&cfg);

            for &tok in &tokens {
                model.decode_step(tok, &mut kv_slab, &mut s_slab).unwrap();
                model.decode_step(tok, &mut kv_paged, &mut s_paged).unwrap();
                // bitwise equality, not tolerance: the paged walk must
                // replay the slab's float op order exactly
                assert_eq!(
                    s_slab.logits, s_paged.logits,
                    "d{d} l{l} h{h} len {} of {n}: paged-f32 diverged",
                    kv_slab.len()
                );
            }
            assert_eq!(kv_slab.len(), kv_paged.len());
        }
    }
}

#[test]
fn paged_f32_block_forward_bit_exact_vs_slab() {
    use gqsa::model::BlockScratch;
    let cfg = small_cfg(64, 2, 2);
    let fp = random_fp(&cfg, 7);
    let model = Transformer::from_fp(&fp).unwrap();
    let tokens: Vec<u32> = (0..(2 * KV_BLOCK + 3)).map(|i| (i % 60) as u32).collect();
    let cap = 4 * KV_BLOCK;

    let mut kv_slab = KvCache::new(cfg.n_layers, cfg.n_heads, cfg.head_dim(), cap);
    let mut bs_slab = BlockScratch::new(&cfg, tokens.len());
    model.forward_block(&tokens, &mut kv_slab, &mut bs_slab).unwrap();

    let pool = KvBlockPool::new(cfg.n_heads, cfg.head_dim(), KvDtype::F32, cfg.n_layers * 8);
    let mut kv_paged = KvCache::paged(cfg.n_layers, &pool, cap);
    let mut bs_paged = BlockScratch::new(&cfg, tokens.len());
    model.forward_block(&tokens, &mut kv_paged, &mut bs_paged).unwrap();

    assert_eq!(bs_slab.logits.data, bs_paged.logits.data, "block forward diverged");
}

#[test]
fn quantized_kv_logits_close_and_q8_tighter_than_q4() {
    let cfg = small_cfg(64, 2, 2);
    let fp = random_fp(&cfg, 9);
    let model = Transformer::from_fp(&fp).unwrap();
    let n = 3 * KV_BLOCK + 2; // sealed quantized blocks + f32 tail
    let tokens: Vec<u32> = (0..n).map(|i| ((i * 5 + 3) % 60) as u32).collect();
    let cap = 4 * KV_BLOCK;

    let logits_for = |dtype: Option<KvDtype>| -> Vec<f32> {
        let mut kv = match dtype {
            None => KvCache::new(cfg.n_layers, cfg.n_heads, cfg.head_dim(), cap),
            Some(d) => {
                let pool = KvBlockPool::new(cfg.n_heads, cfg.head_dim(), d, cfg.n_layers * 8);
                KvCache::paged(cfg.n_layers, &pool, cap)
            }
        };
        let mut s = Scratch::new(&cfg);
        for &tok in &tokens {
            model.decode_step(tok, &mut kv, &mut s).unwrap();
        }
        s.logits.clone()
    };

    let exact = logits_for(None);
    let rel = |a: &[f32]| -> f64 {
        let num: f64 =
            a.iter().zip(&exact).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>().sqrt();
        let den: f64 = exact.iter().map(|y| (*y as f64).powi(2)).sum::<f64>().sqrt();
        num / den.max(1e-12)
    };
    let q8 = logits_for(Some(KvDtype::Q8));
    let q4 = logits_for(Some(KvDtype::Q4));
    assert!(q8.iter().all(|v| v.is_finite()), "q8 produced non-finite logits");
    assert!(q4.iter().all(|v| v.is_finite()), "q4 produced non-finite logits");
    let (r8, r4) = (rel(&q8), rel(&q4));
    // 8-bit KV is a tiny perturbation; 4-bit is bounded but looser
    assert!(r8 < 0.05, "q8 rel logits err {r8}");
    assert!(r4 < 0.5, "q4 rel logits err {r4}");
    assert!(r8 <= r4 + 1e-9, "q8 ({r8}) should not be worse than q4 ({r4})");
}

#[test]
fn truncate_rollback_is_bit_exact_under_decode_across_dtypes() {
    // speculative-style overshoot at the MODEL level: decode a prefix,
    // declare the rollback floor, overshoot past block boundaries,
    // truncate back — subsequent logits must be BIT-IDENTICAL to a
    // cache that never overshot, for f32 and quantized pools alike
    // (quantized blocks restore from their f32 shadows).
    let cfg = small_cfg(64, 2, 2);
    let fp = random_fp(&cfg, 33);
    let model = Transformer::from_fp(&fp).unwrap();
    let prefix = KV_BLOCK - 2; // floor lands just before a boundary
    let overshoot = KV_BLOCK + 5; // seals a block mid-speculation
    let cont: Vec<u32> = (0..(KV_BLOCK + 3)).map(|i| ((i * 7 + 1) % 60) as u32).collect();
    for dtype in [KvDtype::F32, KvDtype::Q8, KvDtype::Q4] {
        let pool = KvBlockPool::new(cfg.n_heads, cfg.head_dim(), dtype, cfg.n_layers * 16);
        let run = |speculate: bool| -> Vec<Vec<f32>> {
            let mut kv = KvCache::paged(cfg.n_layers, &pool, 8 * KV_BLOCK);
            let mut s = Scratch::new(&cfg);
            for t in 0..prefix {
                model.decode_step((t % 60) as u32, &mut kv, &mut s).unwrap();
            }
            if speculate {
                kv.set_commit(prefix);
                for t in 0..overshoot {
                    model.decode_step(((t * 5 + 2) % 60) as u32, &mut kv, &mut s).unwrap();
                }
                assert!(
                    dtype == KvDtype::F32 || kv.shadow_blocks() > 0,
                    "{dtype:?}: no shadow kept across the overshoot seal"
                );
                kv.truncate(prefix);
                kv.set_commit(prefix);
            }
            let mut logits = Vec::new();
            for &tok in &cont {
                model.decode_step(tok, &mut kv, &mut s).unwrap();
                logits.push(s.logits.clone());
            }
            logits
        };
        let clean = run(false);
        let rolled = run(true);
        assert_eq!(clean, rolled, "{dtype:?}: rollback changed post-truncate logits");
        assert_eq!(pool.stats().blocks_in_use, 0, "{dtype:?}: leaked blocks");
    }
}

#[test]
fn pool_survives_1k_request_lifecycles_without_leak_or_double_free() {
    let n_layers = 2;
    let pool = KvBlockPool::new(2, 8, KvDtype::Q8, n_layers * 6);
    let total = pool.total_blocks();
    let mut rng = XorShift::new(42);
    let d = 2 * 8;
    for life in 0..1000u64 {
        let cap = 5 * KV_BLOCK;
        let mut kv = KvCache::paged(n_layers, &pool, cap);
        let n = 1 + rng.below(4 * KV_BLOCK + 3);
        let mut wrote = 0usize;
        'outer: for t in 0..n {
            for l in 0..n_layers {
                let k: Vec<f32> = (0..d).map(|i| (life as f32) + (t * d + i) as f32).collect();
                let v: Vec<f32> = k.iter().map(|x| -x).collect();
                match kv.layers[l].append(&k, &v) {
                    Ok(()) => {}
                    Err(_) => break 'outer, // pool pressure is legal; leaking is not
                }
            }
            wrote += 1;
        }
        // spot-check no stale/poisoned data is visible in-range
        if wrote > 0 {
            let t = wrote - 1;
            let expect0 = (life as f32) + (t * d) as f32;
            assert_eq!(kv.layers[0].key(0, t)[0], expect0, "life {life}: wrong data read back");
        }
        let s = pool.stats();
        assert!(s.blocks_in_use <= total, "life {life}: in_use {} > total", s.blocks_in_use);
        // alternate: half the lifecycles reset explicitly, half drop
        if life % 2 == 0 {
            kv.reset();
            assert_eq!(
                pool.stats().blocks_in_use,
                0,
                "life {life}: reset did not return all blocks"
            );
        }
        drop(kv);
        let s = pool.stats();
        assert_eq!(s.blocks_in_use, 0, "life {life}: leaked blocks");
        assert_eq!(s.allocs, s.frees, "life {life}: alloc/free imbalance (double free?)");
    }
    let s = pool.stats();
    assert!(s.allocs >= 1000, "lifecycles never exercised the pool (allocs {})", s.allocs);
}

#[test]
fn shared_prefix_lifecycle_1k_iterations_no_leak_no_stale_reuse() {
    // interleaved admit / adopt / diverge / retire / evict against one
    // pool and one prefix tree, with a small token alphabet so prompt
    // prefixes genuinely collide. Invariants checked every iteration:
    //   * pool accounting: in_use == tree-held + live-sequence blocks
    //     (no leak), allocs - frees == in_use (no double free),
    //   * adopted data stays finite (never NaN-poisoned) while any
    //     handle references it,
    //   * eviction never claims a block a live sequence adopted.
    let n_layers = 2;
    let d = 2 * 8; // n_heads * head_dim
    let pool = KvBlockPool::new(2, 8, KvDtype::Q8, 48);
    let mut tree = PrefixTree::new(n_layers);
    let mut rng = XorShift::new(2026);
    // deterministic K/V as a function of (token, position) so any two
    // publishers of the same prompt prefix write identical bytes
    let fill = |kv: &mut KvCache, tokens: &[u32], from: usize| {
        for (t, &tok) in tokens.iter().enumerate().skip(from) {
            let k: Vec<f32> =
                (0..d).map(|i| tok as f32 + (t * d + i) as f32 * 0.01).collect();
            let v: Vec<f32> = k.iter().map(|x| -x).collect();
            for l in &mut kv.layers {
                if l.append(&k, &v).is_err() {
                    return; // pool pressure is legal; leaking is not
                }
            }
        }
    };
    let mut live: Vec<(Vec<u32>, KvCache)> = Vec::new();
    for life in 0..1000u64 {
        let action = rng.below(10);
        if action < 6 || live.is_empty() {
            // admit: random prompt over a 3-token alphabet, block-ish lengths
            let plen = 1 + rng.below(4 * KV_BLOCK + 2);
            let prompt: Vec<u32> = (0..plen).map(|_| rng.below(3) as u32).collect();
            let mut kv = KvCache::paged(n_layers, &pool, 8 * KV_BLOCK);
            let hit = tree.lookup(&prompt, blocks_for(plen));
            let adopted = hit.len() * KV_BLOCK;
            if !hit.is_empty() {
                kv.adopt_prefix(&hit);
                // adopted data must be live and finite under refcount
                let mut scratch = Vec::new();
                let seg = kv.layers[0].key_segment(0, 0, &mut scratch);
                assert!(
                    seg.iter().all(|v| v.is_finite()),
                    "life {life}: adopted block was poisoned while referenced"
                );
            }
            fill(&mut kv, &prompt, adopted);
            live.push((prompt, kv));
        } else if action < 8 {
            // retire a random sequence: publish its prompt blocks, drop it
            let idx = rng.below(live.len());
            let (prompt, kv) = live.swap_remove(idx);
            let n = (prompt.len() / KV_BLOCK).min(kv.sealed_blocks_min());
            if n > 0 {
                tree.insert(&prompt, &kv.share_prefix_blocks(n));
            }
            drop(kv);
        } else if action < 9 {
            // diverge: truncate a random sequence mid-stream (possibly
            // into an adopted block — the cow path) and regrow
            let idx = rng.below(live.len());
            let (prompt, kv) = &mut live[idx];
            let to = rng.below(kv.len().max(1));
            kv.truncate(to);
            let regrow: Vec<u32> =
                (0..rng.below(KV_BLOCK + 4)).map(|_| rng.below(3) as u32).collect();
            // regrown positions are NOT the prompt: make them
            // unpublishable by truncating the tracked prompt too
            prompt.truncate(to);
            fill(kv, &regrow, 0);
        } else {
            // pressure: evict LRU unreferenced tree nodes
            tree.evict_lru();
        }
        // pool reconciliation: every in-use block is accounted for by
        // the tree or a live sequence (shared blocks counted once —
        // subtract the overlap, i.e. adopted-and-still-cached blocks)
        let s = pool.stats();
        assert!(
            s.blocks_in_use <= pool.total_blocks(),
            "life {life}: in_use over budget"
        );
        assert_eq!(
            s.allocs - s.frees,
            s.blocks_in_use as u64,
            "life {life}: alloc/free imbalance (double free?)"
        );
        let held_by_seqs: usize = live.iter().map(|(_, kv)| kv.blocks_held()).sum();
        assert!(
            s.blocks_in_use <= tree.shared_blocks() + held_by_seqs,
            "life {life}: in_use {} exceeds all reachable handles ({} cached + {} live)",
            s.blocks_in_use,
            tree.shared_blocks(),
            held_by_seqs
        );
    }
    // teardown: retire everything, drain the tree — nothing may remain
    live.clear();
    while tree.evict_lru() > 0 {}
    let s = pool.stats();
    assert_eq!(s.blocks_in_use, 0, "lifecycle leaked blocks: {s:?}");
    assert_eq!(s.allocs, s.frees, "alloc/free imbalance after teardown: {s:?}");
    assert!(s.allocs > 100, "lifecycles never exercised the pool (allocs {})", s.allocs);
}

#[test]
fn speculative_rollback_on_one_sequence_never_touches_shared_prefix_blocks() {
    // batched-verify audit (fleet speculation): sequences A and B adopt
    // the SAME sealed shared-prefix blocks; A then runs a speculative
    // round — commit floor, overshoot past a block boundary, truncate
    // back — and finally diverges INTO the shared region (the CoW
    // path). B's view of the shared payload must stay byte-identical
    // throughout, and later adopters must still see the original bytes:
    // truncate is strictly local, shared blocks are dropped, never
    // mutated.
    let n_layers = 1;
    let d = 2 * 8; // n_heads * head_dim
    for dtype in [KvDtype::F32, KvDtype::Q8, KvDtype::Q4] {
        let pool = KvBlockPool::new(2, 8, dtype, 32);
        let mut tree = PrefixTree::new(n_layers);
        // 2B+1 tokens: the lazy-seal rule needs the 33rd append to seal
        // the second block, so exactly two blocks are publishable
        let prompt: Vec<u32> = (0..2 * KV_BLOCK + 1).map(|i| (i % 3) as u32).collect();
        // deterministic K/V as a function of (token, position)
        let fill = |kv: &mut KvCache, tokens: &[u32], from: usize| {
            for (t, &tok) in tokens.iter().enumerate() {
                let p = from + t;
                let k: Vec<f32> =
                    (0..d).map(|i| tok as f32 + (p * d + i) as f32 * 0.01).collect();
                let v: Vec<f32> = k.iter().map(|x| -x).collect();
                for l in &mut kv.layers {
                    l.append(&k, &v).unwrap();
                }
            }
        };
        let snap = |kv: &KvCache| -> Vec<Vec<f32>> {
            let mut scratch = Vec::new();
            (0..kv.layers[0].n_segments())
                .map(|seg| kv.layers[0].key_segment(0, seg, &mut scratch).to_vec())
                .collect()
        };
        // publish two sealed prompt blocks, then drop the publisher
        {
            let mut kv = KvCache::paged(n_layers, &pool, 8 * KV_BLOCK);
            fill(&mut kv, &prompt, 0);
            tree.insert(&prompt, &kv.share_prefix_blocks(2));
        }
        let hit = tree.lookup(&prompt, blocks_for(prompt.len()));
        assert_eq!(hit.len(), 2, "{dtype:?}: publisher blocks not cached");
        let mut a = KvCache::paged(n_layers, &pool, 8 * KV_BLOCK);
        a.adopt_prefix(&hit);
        let mut b = KvCache::paged(n_layers, &pool, 8 * KV_BLOCK);
        b.adopt_prefix(&hit);
        // both grow private tails past the adopted region (the hit
        // covers 2 blocks = 2B positions, one short of the prompt)
        let tail_from = a.len();
        assert_eq!(tail_from, 2 * KV_BLOCK, "{dtype:?}: adoption depth");
        fill(&mut a, &[40, 41, 42, 43, 44], tail_from);
        fill(&mut b, &[50, 51, 52, 53, 54], tail_from);
        let before = snap(&b);

        // phase 1: engine-shaped speculative round on A — floor at the
        // current length, overshoot seals a (private) block, roll back
        let floor = a.len();
        a.set_commit(floor);
        let overshoot: Vec<u32> = (0..KV_BLOCK).map(|i| (i % 3) as u32).collect();
        fill(&mut a, &overshoot, floor);
        assert!(
            dtype == KvDtype::F32 || a.shadow_blocks() > 0,
            "{dtype:?}: no shadow kept across the overshoot seal"
        );
        a.truncate(floor);
        a.set_commit(floor);
        assert_eq!(snap(&b), before, "{dtype:?}: rollback mutated B's shared view");

        // phase 2: A diverges INTO the shared region — CoW must copy,
        // not write through the shared payload
        a.truncate(KV_BLOCK + 3);
        fill(&mut a, &[1, 2, 0, 1], KV_BLOCK + 3);
        assert_eq!(snap(&b), before, "{dtype:?}: CoW divergence mutated B's shared view");

        // a fresh adopter still sees the ORIGINAL published bytes
        let hit2 = tree.lookup(&prompt, blocks_for(prompt.len()));
        assert_eq!(hit2.len(), 2, "{dtype:?}: shared blocks vanished from the tree");
        let mut c = KvCache::paged(n_layers, &pool, 8 * KV_BLOCK);
        c.adopt_prefix(&hit2);
        assert_eq!(snap(&c), before[..2].to_vec(), "{dtype:?}: cached payload changed");

        // pool reconciliation and clean teardown
        let s = pool.stats();
        assert_eq!(s.allocs - s.frees, s.blocks_in_use as u64, "{dtype:?}: imbalance {s:?}");
        drop(a);
        drop(b);
        drop(c);
        while tree.evict_lru() > 0 {}
        let s = pool.stats();
        assert_eq!(s.blocks_in_use, 0, "{dtype:?}: leaked blocks {s:?}");
        assert_eq!(s.allocs, s.frees, "{dtype:?}: alloc/free imbalance {s:?}");
    }
}

#[test]
fn stale_data_cannot_survive_block_reuse() {
    // request A fills blocks with a signature, releases them; request B
    // writes different data and must read back ONLY its own values
    // (released blocks are NaN-poisoned, so any stale path would also
    // surface as NaN in the q8 path below)
    let pool = KvBlockPool::new(1, 4, KvDtype::F32, 4);
    let d = 4;
    {
        let mut a = KvCache::paged(1, &pool, 10 * KV_BLOCK);
        for _ in 0..(2 * KV_BLOCK + 1) {
            a.layers[0].append(&[777.0; 4], &[888.0; 4]).unwrap();
        }
    }
    assert_eq!(pool.stats().blocks_in_use, 0);
    let mut b = KvCache::paged(1, &pool, 10 * KV_BLOCK);
    for t in 0..(2 * KV_BLOCK + 1) {
        let k: Vec<f32> = (0..d).map(|i| (t * d + i) as f32 * 0.5).collect();
        let v: Vec<f32> = (0..d).map(|i| (t * d + i) as f32 * 0.25).collect();
        b.layers[0].append(&k, &v).unwrap();
    }
    let mut scratch = Vec::new();
    let mut t = 0usize;
    for seg in 0..b.layers[0].n_segments() {
        let ks = b.layers[0].key_segment(0, seg, &mut scratch).to_vec();
        for row in ks.chunks_exact(d) {
            for (i, val) in row.iter().enumerate() {
                assert!(val.is_finite(), "poisoned value leaked at t{t}");
                assert_eq!(*val, (t * d + i) as f32 * 0.5, "stale data at t{t}");
            }
            t += 1;
        }
    }
    assert_eq!(t, 2 * KV_BLOCK + 1);
}

#[test]
fn pool_alloc_bounded_by_budget() {
    let pool = KvBlockPool::new(1, 4, KvDtype::F32, 3);
    let a = pool.alloc().unwrap();
    let b = pool.alloc().unwrap();
    let c = pool.alloc().unwrap();
    assert!(pool.alloc().is_none(), "budget exceeded");
    assert_eq!(pool.free_blocks(), 0);
    pool.release(b);
    assert_eq!(pool.free_blocks(), 1);
    let b2 = pool.alloc().unwrap();
    assert!(pool.alloc().is_none());
    pool.release(a);
    pool.release(b2);
    pool.release(c);
    assert_eq!(pool.free_blocks(), 3);
    let s = pool.stats();
    assert_eq!(s.allocs, 4);
    assert_eq!(s.frees, 4);
    assert_eq!(s.peak_in_use, 3);
}

#[test]
fn decode_step_returns_typed_cache_full_without_poisoning_state() {
    use gqsa::model::CacheFull;
    let cfg = small_cfg(32, 1, 2);
    let fp = random_fp(&cfg, 21);
    let model = Transformer::from_fp(&fp).unwrap();
    // capacity-limited slab
    let mut kv = KvCache::new(cfg.n_layers, cfg.n_heads, cfg.head_dim(), 3);
    let mut s = Scratch::new(&cfg);
    for tok in [1u32, 2, 3] {
        model.decode_step(tok, &mut kv, &mut s).unwrap();
    }
    let err = model.decode_step(4, &mut kv, &mut s).unwrap_err();
    let cf = err.downcast_ref::<CacheFull>().expect("error should downcast to CacheFull");
    assert!(matches!(cf, CacheFull::Capacity { len: 3, capacity: 3 }));
    assert_eq!(kv.len(), 3, "failed step must not mutate the cache");

    // pool-limited paged cache: typed PoolExhausted, state unpoisoned
    let pool = KvBlockPool::new(cfg.n_heads, cfg.head_dim(), KvDtype::F32, 1);
    let mut kv = KvCache::paged(cfg.n_layers, &pool, 10 * KV_BLOCK);
    for i in 0..(2 * KV_BLOCK) {
        model.decode_step((i % 60) as u32, &mut kv, &mut s).unwrap();
    }
    let len_before = kv.len();
    let err = model.decode_step(5, &mut kv, &mut s).unwrap_err();
    let cf = err.downcast_ref::<CacheFull>().expect("typed CacheFull");
    assert!(matches!(cf, CacheFull::PoolExhausted { .. }), "{cf:?}");
    assert_eq!(kv.len(), len_before);
    // after freeing, the same sequence can continue
    drop(kv);
    assert_eq!(pool.stats().blocks_in_use, 0);
}

#[test]
fn arc_pool_is_shared_across_sequences() {
    let pool = KvBlockPool::new(2, 8, KvDtype::F32, 4);
    let mut a = KvCache::paged(1, &pool, 10 * KV_BLOCK);
    let mut b = KvCache::paged(1, &pool, 10 * KV_BLOCK);
    assert!(Arc::ptr_eq(a.pool().unwrap(), b.pool().unwrap()));
    let d = 16;
    for _ in 0..(KV_BLOCK + 1) {
        a.layers[0].append(&vec![1.0; d], &vec![1.0; d]).unwrap();
        b.layers[0].append(&vec![2.0; d], &vec![2.0; d]).unwrap();
    }
    assert_eq!(pool.stats().blocks_in_use, 2);
    drop(a);
    assert_eq!(pool.stats().blocks_in_use, 1);
    drop(b);
    assert_eq!(pool.stats().blocks_in_use, 0);
}
