//! Integration over the compression pipeline + artifacts: the optimized
//! .gqsa artifacts must load, evaluate sanely, and beat the naive
//! baselines the paper compares against. Artifact-dependent tests skip
//! (not fail) on a fresh checkout.

use std::path::PathBuf;

use gqsa::bench::Workbench;

fn art() -> PathBuf {
    Workbench::default_dir()
}

macro_rules! require {
    ($p:expr) => {
        if !$p.exists() {
            eprintln!("SKIP: {} missing (run `make artifacts`)", $p.display());
            return;
        }
    };
}

#[test]
fn gqsa_artifact_roundtrip_and_accounting() {
    require!(art().join("models/tiny-llama.w4s50g16.gqsa"));
    let gm = gqsa::gqs::format::GqsModel::load(art().join("models/tiny-llama.w4s50g16.gqsa")).unwrap();
    assert_eq!(gm.bits, 4);
    assert_eq!(gm.group, 16);
    assert!((gm.sparsity - 0.5).abs() < 0.02);
    assert_eq!(gm.layers.len(), 28); // 4 blocks x 7 linears
    for (name, layer) in &gm.layers {
        assert!((layer.sparsity() - 0.5).abs() < 0.05, "{name}: {}", layer.sparsity());
        // BSR invariants
        assert_eq!(layer.row_index.len(), layer.rows + 1);
        assert!(layer.row_index.windows(2).all(|w| w[0] <= w[1]), "{name} row_index monotone");
        let ng = (layer.cols / layer.group) as u32;
        assert!(layer.groups.iter().all(|&g| g < ng), "{name} group cols in range");
    }
    // compressed linears must be well under fp32 size
    let fp_linear_bytes: usize = gm
        .config
        .linear_names()
        .iter()
        .map(|n| {
            let (r, c) = gm.config.linear_shape(n);
            r * c * 4
        })
        .sum();
    let ratio = fp_linear_bytes as f64 / gm.gqs_bytes() as f64;
    assert!(ratio > 6.0, "compression ratio {ratio}");
}

#[test]
fn optimized_beats_oneshot_ppl() {
    // Table 6's claim, as a regression test.
    require!(art().join("models/tiny-llama.w4s50g16.gqsa"));
    require!(art().join("models/tiny-llama.w4s50g16-oneshot.gqsa"));
    let mut wb = Workbench::new(art());
    let opt = wb.variant("tiny-llama", "gqsa:w4s50g16").unwrap();
    let oneshot = wb.variant("tiny-llama", "gqsa:w4s50g16-oneshot").unwrap();
    let p_opt = wb.ppl(&opt, "wiki_syn", 4).unwrap();
    let p_one = wb.ppl(&oneshot, "wiki_syn", 4).unwrap();
    assert!(p_opt < p_one, "optimized {p_opt} should beat one-shot {p_one}");
}

#[test]
fn gqsa_w4s30_beats_w2_ppl() {
    // The paper's Table 1 accuracy ordering. At 7B scale the paper shows
    // it for W4S50; our 2.7M-param models lack that much redundancy, so
    // the ordering is asserted at the sparsity where it robustly holds
    // on this substrate (S30 — still 4-bit + structured pruning vs W2).
    // See EXPERIMENTS.md "scale note".
    require!(art().join("models/tiny-llama.w4s30g16.gqsa"));
    let mut wb = Workbench::new(art());
    let gqsa = wb.variant("tiny-llama", "gqsa:w4s30g16").unwrap();
    let w2 = wb.variant("tiny-llama", "w2").unwrap();
    let p_gqsa = wb.ppl(&gqsa, "wiki_syn", 4).unwrap();
    let p_w2 = wb.ppl(&w2, "wiki_syn", 4).unwrap();
    assert!(p_gqsa < p_w2, "gqsa w4s30 {p_gqsa} vs w2 {p_w2}");
}

#[test]
fn gqsa_decode_faster_than_w4() {
    // the paper's headline speed claim (Tables 4/11 shape)
    require!(art().join("models/tiny-llama.w4s50g16.gqsa"));
    let mut wb = Workbench::new(art());
    let gqsa = wb.variant("tiny-llama", "gqsa:w4s50g16").unwrap();
    let w4 = wb.variant("tiny-llama", "w4").unwrap();
    let t_gqsa = wb.decode_latency_ms(&gqsa, 15, 96).unwrap();
    let t_w4 = wb.decode_latency_ms(&w4, 15, 96).unwrap();
    assert!(t_gqsa < t_w4, "gqsa {t_gqsa}ms should beat w4 {t_w4}ms");
}

#[test]
fn sparsity_ladder_monotone_memory() {
    // Fig. 7 bottom / Table 16 memory column shape
    require!(art().join("models/tiny-llama.w4s20g16.gqsa"));
    let mut wb = Workbench::new(art());
    let mut last = usize::MAX;
    for tag in ["w4s20g16", "w4s30g16", "w4s40g16", "w4s50g16"] {
        let m = wb.variant("tiny-llama", &format!("gqsa:{tag}")).unwrap();
        let bytes = m.weight_bytes();
        assert!(bytes < last, "{tag}: {bytes} !< {last}");
        last = bytes;
    }
}

#[test]
fn all_families_have_compressed_artifacts() {
    require!(art().join("models/tiny-qwen.w4s50g16.gqsa"));
    let mut wb = Workbench::new(art());
    for fam in ["tiny-llama", "tiny-gpt", "tiny-qwen"] {
        let m = wb.variant(fam, "gqsa:w4s50g16").unwrap();
        let ppl = wb.ppl(&m, "wiki_syn", 2).unwrap();
        assert!(ppl < 120.0, "{fam}: compressed ppl {ppl} degenerate");
        assert!(ppl > 1.0, "{fam}: ppl {ppl} suspicious");
    }
}

#[test]
fn baseline_variants_all_build_and_eval() {
    require!(art().join("models/tiny-llama.fp.bin"));
    let mut wb = Workbench::new(art());
    for spec in [
        "fp", "w8", "w4", "w2", "24-wanda", "sparse:s50:g16", "struct:25",
        "unstr:s20:w8", "vq-w2", "a8+w4",
    ] {
        let m = wb.variant("tiny-llama", spec).unwrap();
        let ppl = wb.ppl(&m, "wiki_syn", 1).unwrap();
        assert!(ppl.is_finite() && ppl > 1.0, "{spec}: ppl {ppl}");
    }
}

#[test]
fn calibrated_better_than_magnitude_oneshot() {
    // Hessian saliency (Eq. 4) should not lose to magnitude-only.
    require!(art().join("models/tiny-llama.fp.bin"));
    let mut wb = Workbench::new(art());
    let fp = wb.fp("tiny-llama").unwrap();
    let hess = wb.hessians("tiny-llama").unwrap().clone();
    let with_h =
        gqsa::model::Transformer::from_fp_gqs_oneshot(&fp, Some(&hess), 4, 16, 0.5).unwrap();
    let without =
        gqsa::model::Transformer::from_fp_gqs_oneshot(&fp, None, 4, 16, 0.5).unwrap();
    let p_h = wb.ppl(&with_h, "wiki_syn", 4).unwrap();
    let p_m = wb.ppl(&without, "wiki_syn", 4).unwrap();
    assert!(
        p_h < p_m * 1.05,
        "hessian saliency {p_h} should be no worse than magnitude {p_m}"
    );
}
