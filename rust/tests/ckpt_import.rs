//! Integration: the safetensors import path. A malformed-header corpus
//! must come back as typed [`CkptError`]s — never a panic, never a read
//! outside the mapping — and a writer→reader→encode→greedy round trip
//! must produce token-identical output to the in-memory build.
//!
//! The round-trip tests run with `CkptOptions::default()` on BOTH
//! sides, so the `GQSA_OUTLIERS=1.0` CI leg pushes the dense-and-sparse
//! outlier decomposition through the whole serving stack.

use std::path::PathBuf;

use gqsa::ckpt::{
    encode_transformer, load_fp, load_transformer, write_fp, CkptEncode, CkptError, CkptOptions,
    SafeTensors, SafeTensorsWriter, StDtype,
};
use gqsa::coordinator::{Backend, EngineConfig, EngineCore, Request};
use gqsa::model::config::demo_config;
use gqsa::model::transformer::{random_fp, LinearKind, Transformer};
use gqsa::model::ModelConfig;

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("gqsa_ckpt_{}_{}.safetensors", tag, std::process::id()))
}

/// Author a raw file: 8-byte LE header length + header bytes + data.
fn raw_file(tag: &str, header: &[u8], data: &[u8]) -> PathBuf {
    let p = tmp(tag);
    let mut out = Vec::with_capacity(8 + header.len() + data.len());
    out.extend_from_slice(&(header.len() as u64).to_le_bytes());
    out.extend_from_slice(header);
    out.extend_from_slice(data);
    std::fs::write(&p, out).unwrap();
    p
}

/// `SafeTensors` carries a raw mapping and has no `Debug` impl, so the
/// corpus tests extract the error without `unwrap_err`.
fn open_err(p: &std::path::Path) -> CkptError {
    SafeTensors::open(p).err().expect("malformed checkpoint was accepted")
}

fn tiny_config() -> ModelConfig {
    let mut cfg = demo_config();
    cfg.d_model = 32;
    cfg.n_layers = 2;
    cfg.n_heads = 2;
    cfg.d_ff = 48;
    cfg.vocab = 48;
    cfg.max_seq = 96;
    cfg
}

fn greedy_tokens(t: Transformer, prompt: &[u32], n: usize) -> Vec<u32> {
    let cfg = t.cfg.clone();
    let mut e = EngineCore::new(
        Backend::Native(t),
        &cfg,
        EngineConfig { max_batch: 1, prefill_chunk: 8, kv_capacity: 96, ..Default::default() },
    )
    .unwrap();
    e.submit(Request::new(0, prompt.to_vec(), n));
    e.run_to_completion().unwrap()[0].tokens.clone()
}

// ---------------------------------------------------------------- corpus

#[test]
fn file_shorter_than_length_prefix_is_truncated() {
    let p = tmp("trunc");
    std::fs::write(&p, [0u8; 4]).unwrap();
    assert_eq!(open_err(&p), CkptError::Truncated { need: 8, have: 4 });
    std::fs::write(&p, b"").unwrap();
    assert_eq!(open_err(&p), CkptError::Truncated { need: 8, have: 0 });
    std::fs::remove_file(&p).ok();
}

#[test]
fn declared_header_longer_than_file_is_header_past_eof() {
    let p = tmp("eof");
    let mut out = u64::MAX.to_le_bytes().to_vec();
    out.extend_from_slice(b"{}");
    std::fs::write(&p, out).unwrap();
    match open_err(&p) {
        CkptError::HeaderPastEof { header_len, file_len } => {
            assert_eq!(header_len, u64::MAX);
            assert_eq!(file_len, 10);
        }
        e => panic!("want HeaderPastEof, got {e:?}"),
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn non_json_and_non_object_headers_are_bad_header() {
    for (tag, header) in [
        ("garbage", &b"!!not json!!"[..]),
        ("utf8", &[0xffu8, 0xfe, 1, 2][..]),
        ("arr", &b"[1,2]"[..]),
    ] {
        let p = raw_file(&format!("bad_{tag}"), header, &[]);
        assert!(
            matches!(SafeTensors::open(&p), Err(CkptError::BadHeader(_))),
            "{tag}: want BadHeader"
        );
        std::fs::remove_file(&p).ok();
    }
}

#[test]
fn unsupported_dtype_is_unknown_dtype() {
    let header = br#"{"t":{"dtype":"I64","shape":[2],"data_offsets":[0,16]}}"#;
    let p = raw_file("dtype", header, &[0u8; 16]);
    assert_eq!(
        open_err(&p),
        CkptError::UnknownDtype { name: "t".into(), dtype: "I64".into() }
    );
    std::fs::remove_file(&p).ok();
}

#[test]
fn offsets_outside_data_region_are_out_of_bounds() {
    // 4 bytes of data, offsets claim 8
    let header = br#"{"t":{"dtype":"F32","shape":[2],"data_offsets":[0,8]}}"#;
    let p = raw_file("oob", header, &[0u8; 4]);
    assert_eq!(
        open_err(&p),
        CkptError::OutOfBounds { name: "t".into(), begin: 0, end: 8, data_len: 4 }
    );
    std::fs::remove_file(&p).ok();

    // begin > end is the same class of error
    let header = br#"{"t":{"dtype":"F32","shape":[1],"data_offsets":[8,4]}}"#;
    let p = raw_file("oob2", header, &[0u8; 16]);
    assert!(matches!(SafeTensors::open(&p), Err(CkptError::OutOfBounds { .. })));
    std::fs::remove_file(&p).ok();
}

#[test]
fn shape_disagreeing_with_span_is_shape_mismatch() {
    // shape [3] x f32 needs 12 bytes but the span is 8
    let header = br#"{"t":{"dtype":"F32","shape":[3],"data_offsets":[0,8]}}"#;
    let p = raw_file("shape", header, &[0u8; 8]);
    assert_eq!(
        open_err(&p),
        CkptError::ShapeMismatch { name: "t".into(), need_bytes: 12, span: 8 }
    );
    std::fs::remove_file(&p).ok();
}

#[test]
fn overlapping_tensor_spans_are_rejected() {
    let header = concat!(
        r#"{"a":{"dtype":"F32","shape":[2],"data_offsets":[0,8]},"#,
        r#""b":{"dtype":"F32","shape":[2],"data_offsets":[4,12]}}"#
    );
    let p = raw_file("overlap", header.as_bytes(), &[0u8; 12]);
    assert_eq!(
        open_err(&p),
        CkptError::Overlap { name: "b".into(), prev: "a".into() }
    );
    std::fs::remove_file(&p).ok();
}

#[test]
fn missing_tensor_is_a_typed_error_not_a_panic() {
    let mut w = SafeTensorsWriter::new();
    w.add_f32("present", &[2, 2], &[1.0, 2.0, 3.0, 4.0]);
    let p = tmp("missing");
    w.write(&p).unwrap();
    let st = SafeTensors::open(&p).unwrap();
    assert_eq!(st.f32_vec("absent").unwrap_err(), CkptError::MissingTensor("absent".into()));
    assert!(st.f32_vec("present").is_ok());
    std::fs::remove_file(&p).ok();
}

#[test]
fn corpus_of_random_truncations_never_panics() {
    // a valid checkpoint chopped at every prefix length must always
    // come back as Err, never panic or read out of bounds
    let mut w = SafeTensorsWriter::new();
    w.metadata("k", "v");
    w.add_f32("t", &[4], &[1.0, 2.0, 3.0, 4.0]);
    let p = tmp("chop_src");
    w.write(&p).unwrap();
    let full = std::fs::read(&p).unwrap();
    std::fs::remove_file(&p).ok();
    let q = tmp("chop");
    for cut in 0..full.len() {
        std::fs::write(&q, &full[..cut]).unwrap();
        assert!(SafeTensors::open(&q).is_err(), "prefix of {cut} bytes accepted");
    }
    // the untruncated file still parses
    std::fs::write(&q, &full).unwrap();
    assert!(SafeTensors::open(&q).is_ok());
    std::fs::remove_file(&q).ok();
}

// ------------------------------------------------------------- read paths

#[test]
fn f16_and_bf16_payloads_decode_through_their_conversions() {
    use gqsa::ckpt::safetensors::{f16_to_f32, f32_to_bf16, f32_to_f16};
    let vals = [0.0f32, 1.0, -2.5, 0.000123, 65000.0, -0.333];
    let mut w = SafeTensorsWriter::new();
    w.add_f32("f32", &[vals.len()], &vals);
    w.add_f32_as("f16", StDtype::F16, &[vals.len()], &vals);
    w.add_f32_as("bf16", StDtype::BF16, &[vals.len()], &vals);
    let p = tmp("dtypes");
    w.write(&p).unwrap();
    let st = SafeTensors::open(&p).unwrap();
    assert_eq!(st.f32_vec("f32").unwrap(), vals);
    let via_f16: Vec<f32> = vals.iter().map(|&v| f16_to_f32(f32_to_f16(v))).collect();
    let via_bf16: Vec<f32> =
        vals.iter().map(|&v| f32::from_bits((f32_to_bf16(v) as u32) << 16)).collect();
    for (name, expect) in [("f16", via_f16), ("bf16", via_bf16)] {
        let got = st.f32_vec(name).unwrap();
        assert_eq!(got, expect, "{name} narrow round-trip");
        // and the narrowing really happened: within ~1% of source
        for (g, v) in got.iter().zip(&vals) {
            let tol = v.abs() * 0.01 + 1e-4;
            assert!((g - v).abs() <= tol, "{name}: {g} vs {v}");
        }
    }
    std::fs::remove_file(&p).ok();
}

// ------------------------------------------------------------ round trips

#[test]
fn zero_outliers_load_is_bit_identical_and_greedy_matches_in_memory() {
    let cfg = tiny_config();
    let fp = random_fp(&cfg, 907);
    let p = tmp("bitident");
    write_fp(&fp, &p).unwrap();

    let opts = CkptOptions {
        encode: CkptEncode::Gqs { bits: 4, group: 16, sparsity: 0.5 },
        outlier_pct: 0.0,
    };
    let (from_disk, report) = load_transformer(&p, &opts).unwrap();
    let in_memory = Transformer::from_fp_gqs_oneshot(&fp, None, 4, 16, 0.5).unwrap();

    assert_eq!(report.wrapped_layers, 0);
    assert_eq!(report.outlier_nnz, 0);
    for (name, la) in &from_disk.linears {
        assert!(!matches!(la, LinearKind::Outlier(_)), "{name} wrapped at pct=0");
        assert_eq!(
            la.decode_dense().data,
            in_memory.linears[name].decode_dense().data,
            "{name}: on-disk encode diverged bitwise from the in-memory path"
        );
    }

    let prompt: Vec<u32> = (0..12).map(|i| (i * 3) % cfg.vocab as u32).collect();
    let a = greedy_tokens(from_disk, &prompt, 20);
    let b = greedy_tokens(in_memory, &prompt, 20);
    assert_eq!(a, b, "greedy decode diverged between disk and memory builds");
    std::fs::remove_file(&p).ok();
}

#[test]
fn writer_reader_encode_greedy_round_trip_matches_in_memory_engine() {
    // env-default options on BOTH sides: under GQSA_OUTLIERS=1.0 this
    // drives the outlier CSR through prefill + decode end to end
    let cfg = tiny_config();
    let fp = random_fp(&cfg, 911);
    let p = tmp("roundtrip");
    write_fp(&fp, &p).unwrap();

    let opts = CkptOptions::default();
    let back = load_fp(&p).unwrap();
    assert_eq!(back.config.to_json().to_string(), cfg.to_json().to_string());
    for (name, m) in &fp.weights {
        assert_eq!(&back.weights[name].data, &m.data, "{name}: fp payload changed on disk");
    }

    let (from_disk, report) = load_transformer(&p, &opts).unwrap();
    let in_memory = encode_transformer(&fp, &opts).unwrap();
    if opts.outlier_pct > 0.0 {
        assert!(report.wrapped_layers > 0, "outlier pct {} wrapped nothing", opts.outlier_pct);
    }

    let prompt: Vec<u32> = (0..10).map(|i| (i * 5 + 1) % cfg.vocab as u32).collect();
    let a = greedy_tokens(from_disk, &prompt, 24);
    let b = greedy_tokens(in_memory, &prompt, 24);
    assert_eq!(a.len(), 24);
    assert!(a.iter().all(|&t| t < cfg.vocab as u32));
    assert_eq!(a, b, "on-disk and in-memory engines disagree on greedy tokens");
    std::fs::remove_file(&p).ok();
}

#[test]
fn fp_checkpoint_roundtrip_preserves_exact_logits_source() {
    // Fp encode of the on-disk file == from_fp of the original: the
    // whole file path (write, mmap, header parse, f32 decode) is exact
    let cfg = tiny_config();
    let fp = random_fp(&cfg, 919);
    let p = tmp("fp_exact");
    write_fp(&fp, &p).unwrap();
    let opts = CkptOptions { encode: CkptEncode::Fp, outlier_pct: 0.0 };
    let (from_disk, _) = load_transformer(&p, &opts).unwrap();
    let in_memory = Transformer::from_fp(&fp).unwrap();
    let prompt: Vec<u32> = (0..8).map(|i| (i * 7 + 2) % cfg.vocab as u32).collect();
    assert_eq!(
        greedy_tokens(from_disk, &prompt, 16),
        greedy_tokens(in_memory, &prompt, 16),
        "fp import is not exact"
    );
    std::fs::remove_file(&p).ok();
}
