//! Multi-shard router tests: the three serving-loop delivery fixes
//! (burst admission, finished-work delivery on an engine error,
//! duplicate-id rejection) plus shard routing, prefix affinity and
//! drain/replay. Every test pins `RouterConfig { shards }` explicitly
//! so results do not depend on the `GQSA_SHARDS` env (CI runs the
//! whole suite under GQSA_SHARDS=2 as well).

use std::time::Duration;

use gqsa::coordinator::{
    Backend, EngineConfig, EngineCore, FinishReason, Metrics, Request, Router, RouterConfig,
};
use gqsa::model::config::demo_config;
use gqsa::model::transformer::{random_fp, Transformer};

/// Tiny deterministic engine. `delay_ms` stalls the build on the shard
/// thread so requests submitted meanwhile queue up in the channel —
/// the deterministic way to present the serving loop with a burst.
fn build_engine(
    max_batch: usize,
    delay_ms: u64,
    chaos_fail_tick: Option<u64>,
    prefix_cache: bool,
) -> anyhow::Result<EngineCore> {
    if delay_ms > 0 {
        std::thread::sleep(Duration::from_millis(delay_ms));
    }
    let mut cfg = demo_config();
    cfg.d_model = 64;
    cfg.n_layers = 1;
    cfg.n_heads = 2;
    cfg.d_ff = 96;
    cfg.vocab = 64;
    cfg.max_seq = 96;
    let t = Transformer::from_fp(&random_fp(&cfg, 33))?;
    let mut e = EngineCore::new(
        Backend::Native(t),
        &cfg,
        EngineConfig {
            max_batch,
            prefill_chunk: 8,
            kv_capacity: 96,
            spec_k: 0,
            prefix_cache,
            ..Default::default()
        },
    )?;
    e.chaos_fail_tick = chaos_fail_tick;
    Ok(e)
}

/// Bugfix 1: a burst of submits is admitted together (the loop drains
/// its whole message backlog before ticking), not one per engine tick.
/// All 8 requests land in the first tick, so the engine sees all 8
/// concurrently active.
#[test]
fn burst_submits_admit_in_one_tick() {
    let router = Router::start(RouterConfig { shards: 1 }, |_s| build_engine(8, 300, None, false));
    let mut rxs = Vec::new();
    for i in 0..8u64 {
        rxs.push(router.submit(Request::new(i, vec![(i % 60) as u32 + 1; 8], 4)).unwrap());
    }
    for rx in rxs {
        let r = rx.recv().unwrap();
        assert_eq!(r.tokens.len(), 4);
        assert_eq!(r.finish, FinishReason::Length);
    }
    let report = router.metrics_report();
    assert!(report.contains("peak_active=8"), "burst not co-admitted: {report}");
    router.shutdown();
}

/// Bugfix 2: when a tick errors, work that already finished is still
/// delivered, and every still-pending request gets a typed
/// `EngineError` response instead of a dropped channel.
#[test]
fn tick_error_delivers_finished_and_fails_pending() {
    let router =
        Router::start(RouterConfig { shards: 1 }, |_s| build_engine(4, 200, Some(3), false));
    let rx1 = router.submit(Request::new(1, vec![1, 2, 3], 1)).unwrap();
    let rx2 = router.submit(Request::new(2, vec![4, 5, 6], 50)).unwrap();
    // finishes within the first ticks, before the injected failure
    let r1 = rx1.recv().unwrap();
    assert_eq!(r1.tokens.len(), 1);
    assert_eq!(r1.finish, FinishReason::Length);
    // still mid-decode at the failure: typed error, not a hangup
    let r2 = rx2.recv().unwrap();
    assert_eq!(r2.finish, FinishReason::EngineError);
    assert!(r2.tokens.is_empty());
    router.shutdown();
}

/// Bugfix 3: a second in-flight request with the same id is rejected
/// with a typed response; the first keeps its reply slot and the id
/// becomes reusable once its response is delivered.
#[test]
fn duplicate_id_rejected_then_reusable() {
    let router = Router::start(RouterConfig { shards: 1 }, |_s| build_engine(2, 200, None, false));
    let rx_first = router.submit(Request::new(7, vec![1; 8], 24)).unwrap();
    let rx_dup = router.submit(Request::new(7, vec![2; 8], 4)).unwrap();
    let dup = rx_dup.recv().unwrap();
    assert_eq!(dup.finish, FinishReason::DuplicateId);
    assert!(dup.tokens.is_empty());
    let first = rx_first.recv().unwrap();
    assert_eq!(first.finish, FinishReason::Length);
    assert_eq!(first.tokens.len(), 24);
    // delivery unregisters the id
    let again = router.generate(Request::new(7, vec![3; 8], 2)).unwrap();
    assert_eq!(again.finish, FinishReason::Length);
    assert_eq!(again.tokens.len(), 2);
    router.shutdown();
}

/// Routing must never change outputs: the same disjoint request set
/// produces token-identical greedy results on 1 and 2 shards (shards
/// rebuild identical weights from the seed).
#[test]
fn two_shards_token_identical_to_one() {
    fn run_fleet(shards: usize) -> Vec<Vec<u32>> {
        let router = Router::start(RouterConfig { shards }, |_s| build_engine(4, 0, None, false));
        let mut rxs = Vec::new();
        for i in 0..10u64 {
            // >= one full KV block and distinct per request, so every
            // request fingerprints differently (pure balance routing)
            let prompt: Vec<u32> =
                (0..20).map(|j| ((i as usize * 17 + j * 3 + 1) % 60) as u32).collect();
            rxs.push(router.submit(Request::new(i, prompt, 6)).unwrap());
        }
        let mut out: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        out.sort_by_key(|r| r.id);
        let report = router.metrics_report();
        router.shutdown();
        if shards > 1 {
            assert!(report.starts_with("shards=2 | requests=10"), "{report}");
            assert!(report.contains("shard[0]") && report.contains("shard[1]"), "{report}");
        }
        out.into_iter()
            .inspect(|r| assert_eq!(r.finish, FinishReason::Length))
            .map(|r| r.tokens)
            .collect()
    }
    assert_eq!(run_fleet(1), run_fleet(2));
}

/// Prefix affinity keeps prompt families on the shard that already
/// holds their sealed blocks: scaling 1 -> 2 shards loses no prefix
/// hits (and changes no tokens).
#[test]
fn prefix_affinity_preserves_hit_rate_across_shards() {
    fn run_families(shards: usize) -> (u64, u64, Vec<Vec<u32>>) {
        let router = Router::start(RouterConfig { shards }, |_s| build_engine(4, 0, None, true));
        let mut toks = Vec::new();
        for i in 0..12u64 {
            // two families, each sharing a 32-token (2 KV blocks)
            // system prefix + unique 8-token tail
            let fam = (i % 2) as usize;
            let mut p: Vec<u32> =
                (0..32).map(|j| ((fam * 13 + j * 5 + 1) % 60) as u32).collect();
            p.extend((32..40).map(|j| ((i as usize * 17 + j * 3 + 2) % 60) as u32));
            // sequential so each request sees its predecessors' blocks
            let r = router.generate(Request::new(i, p, 6)).unwrap();
            assert_eq!(r.finish, FinishReason::Length);
            toks.push(r.tokens);
        }
        let mut agg = Metrics::default();
        for m in router.shard_metrics() {
            agg.merge(&m);
        }
        router.shutdown();
        let p = agg.prefix.unwrap_or_default();
        (p.hits, p.misses, toks)
    }
    let (h1, m1, t1) = run_families(1);
    let (h2, m2, t2) = run_families(2);
    assert_eq!(t1, t2, "sharding changed tokens");
    assert_eq!(h1 + m1, h2 + m2, "lookup totals diverged");
    assert!(h1 > 0, "baseline saw no prefix hits");
    assert!(h2 >= h1, "affinity lost hits: {h2} < {h1}");
}

/// Drain replays every request that has not produced a token onto the
/// surviving shards with reply channels intact — no request is lost —
/// and restart re-enables the shard for routing.
#[test]
fn drain_replays_queued_requests_without_loss() {
    let router = Router::start(RouterConfig { shards: 2 }, |_s| build_engine(1, 400, None, false));
    let mut rxs = Vec::new();
    for i in 0..8u64 {
        // identical first block -> one fingerprint -> all 8 pin to the
        // same shard (index 0 by the deterministic tie-break)
        let mut p: Vec<u32> = (0..16).map(|j| ((j * 5 + 1) % 60) as u32).collect();
        p.extend([(i % 60) as u32 + 1, (i % 60) as u32 + 2]);
        rxs.push(router.submit(Request::new(i, p, 2)).unwrap());
    }
    // shard 0 is still building (delayed), so everything is queued and
    // the drain pulls back all 8 for replay on shard 1
    let replayed = router.drain(0).unwrap();
    assert_eq!(replayed, 8, "queued requests not replayed");
    for rx in rxs {
        let r = rx.recv().unwrap();
        assert_eq!(r.finish, FinishReason::Length);
        assert_eq!(r.tokens.len(), 2);
    }
    // with shard 0 draining there is no second live shard to absorb 1
    assert!(router.drain(1).is_err());
    router.restart(0).unwrap();
    assert!(router.drain(1).is_ok());
    router.shutdown();
}
