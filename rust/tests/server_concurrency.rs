//! Serving-layer hardening: N client threads hammer the threaded
//! `Server` with interleaved prefill/decode requests; per-request
//! outputs must be identical to serial submission (continuous batching
//! + the paged KV pool must never let batch-mates contaminate each
//! other), and `EngineCore::take_finished` must deliver every response
//! exactly once.

use std::collections::HashMap;

use gqsa::coordinator::{Backend, EngineConfig, EngineCore, Request, Server};
use gqsa::model::config::demo_config;
use gqsa::model::transformer::{random_fp, Transformer};
use gqsa::model::ModelConfig;

fn cfg() -> ModelConfig {
    let mut cfg = demo_config();
    cfg.d_model = 64;
    cfg.n_layers = 1;
    cfg.n_heads = 2;
    cfg.d_ff = 96;
    cfg.vocab = 64;
    cfg.max_seq = 128;
    cfg
}

fn engine() -> anyhow::Result<EngineCore> {
    let cfg = cfg();
    let t = Transformer::from_fp(&random_fp(&cfg, 33)).unwrap();
    EngineCore::new(
        Backend::Native(t),
        &cfg,
        EngineConfig { max_batch: 4, prefill_chunk: 8, kv_capacity: 128, ..Default::default() },
    )
}

/// Mixed traffic: short prompts, long prompts (multi-chunk prefill),
/// and varying decode lengths so prefill and decode interleave in the
/// engine across requests.
fn workload() -> Vec<Request> {
    (0..12u64)
        .map(|i| {
            let plen = 2 + (i as usize * 5) % 23;
            let prompt: Vec<u32> = (0..plen).map(|j| ((i as usize * 11 + j) % 60) as u32).collect();
            Request::new(i, prompt, 3 + (i as usize * 7) % 10)
        })
        .collect()
}

#[test]
fn concurrent_interleaved_submission_matches_serial() {
    // serial reference: one request at a time through its own server
    let serial: HashMap<u64, Vec<u32>> = {
        let srv = Server::start(engine);
        let client = srv.client();
        let out: HashMap<u64, Vec<u32>> = workload()
            .into_iter()
            .map(|req| {
                let id = req.id;
                (id, client.generate(req).unwrap().tokens)
            })
            .collect();
        srv.shutdown();
        out
    };

    // concurrent: every request on its own thread against one server,
    // all in flight at once (forces batched prefill/decode interleaving)
    let srv = Server::start(engine);
    let mut handles = Vec::new();
    for req in workload() {
        let c = srv.client();
        handles.push(std::thread::spawn(move || {
            let id = req.id;
            (id, c.generate(req).unwrap())
        }));
    }
    let mut seen = HashMap::new();
    for h in handles {
        let (id, resp) = h.join().unwrap();
        assert_eq!(resp.id, id, "response routed to the wrong client");
        assert!(seen.insert(id, resp.tokens).is_none(), "duplicate response for id {id}");
    }
    assert_eq!(seen.len(), serial.len(), "responses dropped");
    for (id, tokens) in &serial {
        assert_eq!(
            seen.get(id),
            Some(tokens),
            "request {id}: concurrent tokens differ from serial submission"
        );
    }
}

#[test]
fn take_finished_delivers_every_response_exactly_once() {
    let mut e = engine().unwrap();
    let reqs = workload();
    let n = reqs.len();
    // stagger submissions between ticks to interleave admission,
    // prefill, decode, and retirement
    let mut pending = reqs.into_iter();
    let mut collected: HashMap<u64, usize> = HashMap::new();
    let mut ticks = 0usize;
    loop {
        for req in pending.by_ref().take(2) {
            e.submit(req);
        }
        if !e.has_work() && collected.len() == n {
            break;
        }
        e.tick().unwrap();
        // draining twice must never duplicate: the second take is empty
        for r in e.take_finished() {
            *collected.entry(r.id).or_insert(0) += 1;
        }
        assert!(e.take_finished().is_empty(), "double drain returned responses");
        ticks += 1;
        assert!(ticks < 10_000, "engine failed to converge");
    }
    assert_eq!(collected.len(), n, "responses dropped: {collected:?}");
    assert!(
        collected.values().all(|&c| c == 1),
        "duplicated responses: {collected:?}"
    );
}

#[test]
fn metrics_report_consistent_after_concurrent_load() {
    let srv = Server::start(engine);
    let mut handles = Vec::new();
    for req in workload() {
        let c = srv.client();
        handles.push(std::thread::spawn(move || c.generate(req).unwrap()));
    }
    for h in handles {
        h.join().unwrap();
    }
    let report = srv.client().metrics_report().unwrap();
    assert!(report.contains("requests=12"), "{report}");
    assert!(report.contains("kv:"), "report should carry KV counters: {report}");
    srv.shutdown();
}
