//! Observability contract tests:
//!
//! * **bit-exactness** — greedy fleet output is TOKEN-IDENTICAL with the
//!   span recorder forced on vs off, across shard counts {1, 2} and
//!   speculative decoding {off, on}. Tracing observes the engine, it
//!   never perturbs it;
//! * **span surface** — one traced fleet run records spans at every
//!   instrumented layer (router dispatch, queue wait, engine tick,
//!   prefill, decode/spec), with parent links that resolve (a prefill
//!   chunk nests under its engine tick);
//! * **exports** — the Chrome trace JSON parses and carries shard pids;
//!   Prometheus text rendered from live shard metrics passes the
//!   exposition-format validator and includes the latency histograms.
//!
//! `obs::force`/`reset` are process-global, so every test here
//! serializes on one mutex (this binary is its own process — the lib's
//! unit tests can't interfere).

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

use gqsa::coordinator::{Backend, EngineConfig, EngineCore, Request, Router, RouterConfig};
use gqsa::model::config::demo_config;
use gqsa::model::transformer::{random_fp, Transformer};
use gqsa::model::ModelConfig;
use gqsa::obs;
use gqsa::util::Json;

static ENV_LOCK: OnceLock<Mutex<()>> = OnceLock::new();

fn lock() -> MutexGuard<'static, ()> {
    ENV_LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

fn cfg() -> ModelConfig {
    let mut cfg = demo_config();
    cfg.d_model = 64;
    cfg.n_layers = 2;
    cfg.n_heads = 2;
    cfg.d_ff = 96;
    cfg.vocab = 64;
    cfg.max_seq = 96;
    cfg
}

/// Run an 8-request greedy fleet on a fresh router; returns the sorted
/// token outputs. Identical seeds per shard, so shard count can never
/// change tokens.
fn run_fleet(shards: usize, spec_k: usize) -> Vec<Vec<u32>> {
    let cfg = Arc::new(cfg());
    let cfg2 = Arc::clone(&cfg);
    let router = Router::start(RouterConfig { shards }, move |_shard| {
        let t = Transformer::from_fp_gqs_oneshot(&random_fp(&cfg2, 919), None, 4, 16, 0.5)?;
        EngineCore::new(
            Backend::Native(t),
            &cfg2,
            EngineConfig {
                max_batch: 4,
                prefill_chunk: 8,
                kv_capacity: 96,
                spec_k,
                ..Default::default()
            },
        )
    });
    let mut rxs = Vec::new();
    for i in 0..8u64 {
        let plen = 10 + (i as usize % 5);
        let prompt: Vec<u32> =
            (0..plen).map(|j| ((i * 7 + j as u64 * 3 + 1) % 60) as u32).collect();
        rxs.push(router.submit(Request::new(i, prompt, 12)).unwrap());
    }
    let mut out: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
    router.shutdown();
    out.sort_by_key(|r| r.id);
    out.into_iter().map(|r| r.tokens).collect()
}

#[test]
fn tracing_never_changes_greedy_tokens() {
    let _g = lock();
    for shards in [1usize, 2] {
        for spec_k in [0usize, 4] {
            obs::force(false);
            let off = run_fleet(shards, spec_k);
            obs::force(true);
            let on = run_fleet(shards, spec_k);
            obs::reset();
            assert_eq!(
                off, on,
                "tracing changed tokens (shards={shards}, spec_k={spec_k})"
            );
            assert_eq!(off.len(), 8);
            assert!(off.iter().all(|t| t.len() == 12));
        }
    }
}

#[test]
fn traced_run_covers_every_layer_with_resolving_parents() {
    let _g = lock();
    obs::force(true);
    obs::clear();
    // spec fleet for the speculative spans, plain fleet for decode_batch
    let _ = run_fleet(2, 4);
    let _ = run_fleet(1, 0);
    let spans = obs::snapshot();
    obs::reset();
    assert!(!spans.is_empty(), "traced run recorded nothing");

    let names: HashSet<&str> = spans.iter().map(|s| s.name).collect();
    for expect in [
        "route_dispatch",
        "queue_wait",
        "engine_tick",
        "prefill_chunk",
        "decode_batch",
        "spec_draft",
        "spec_verify",
    ] {
        assert!(names.contains(expect), "no '{expect}' span in {names:?}");
    }

    // shard tagging: engine-side spans carry a real shard index
    assert!(
        spans.iter().any(|s| s.name == "engine_tick" && s.shard != obs::NO_SHARD),
        "engine ticks missing shard tags"
    );

    // linkage: some prefill chunk nests under an engine tick on record
    let by_id: HashMap<u32, &str> =
        spans.iter().map(|s| (s.id, s.name)).collect();
    assert!(
        spans.iter().any(|s| {
            s.name == "prefill_chunk"
                && s.parent != obs::NO_PARENT
                && by_id.get(&s.parent) == Some(&"engine_tick")
        }),
        "no prefill chunk linked to its engine tick"
    );

    // queue_wait spans are tied to real request ids (not NO_SEQ)
    assert!(
        spans.iter().any(|s| s.name == "queue_wait" && s.seq_id < 8),
        "queue waits not attributed to request ids"
    );
}

#[test]
fn disabled_recorder_stays_silent() {
    let _g = lock();
    obs::force(false);
    obs::clear();
    let before = obs::spans_recorded();
    let _ = run_fleet(1, 4);
    let after = obs::spans_recorded();
    obs::reset();
    assert_eq!(before, after, "spans recorded while tracing was off");
}

#[test]
fn chrome_trace_export_parses_with_shard_pids() {
    let _g = lock();
    obs::force(true);
    obs::clear();
    let _ = run_fleet(2, 0);
    let spans = obs::snapshot();
    let json = gqsa::obs::trace::chrome_trace_json(&spans);
    obs::reset();

    let j = Json::parse(&json).unwrap_or_else(|e| panic!("trace JSON unparseable: {e}"));
    let events = j.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    let complete: Vec<&Json> = events
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
        .collect();
    assert_eq!(complete.len(), spans.len(), "one X event per span");
    // engine spans land in shard processes (pid = shard + 1), and the
    // metadata events name them
    assert!(
        complete.iter().any(|e| e.get("pid").and_then(Json::as_u64) == Some(1)),
        "no event attributed to shard 0"
    );
    assert!(
        events.iter().any(|e| e.get("ph").and_then(Json::as_str) == Some("M")),
        "no process_name metadata events"
    );
}

#[test]
fn prometheus_render_of_live_fleet_validates() {
    let _g = lock();
    let cfg = Arc::new(cfg());
    let cfg2 = Arc::clone(&cfg);
    let router = Router::start(RouterConfig { shards: 2 }, move |_shard| {
        let t = Transformer::from_fp_gqs_oneshot(&random_fp(&cfg2, 919), None, 4, 16, 0.5)?;
        EngineCore::new(
            Backend::Native(t),
            &cfg2,
            EngineConfig {
                max_batch: 4,
                prefill_chunk: 8,
                kv_capacity: 96,
                spec_k: 2,
                ..Default::default()
            },
        )
    });
    for i in 0..6u64 {
        let prompt: Vec<u32> = (0..12).map(|j| ((i * 5 + j * 3 + 1) % 60) as u32).collect();
        router.generate(Request::new(i, prompt, 8)).unwrap();
    }
    let shard_metrics = router.shard_metrics();
    router.shutdown();
    assert_eq!(shard_metrics.len(), 2);

    let text = gqsa::obs::prom::render(&shard_metrics, None);
    gqsa::obs::prom::validate(&text).unwrap_or_else(|e| panic!("invalid exposition: {e}\n{text}"));
    for fam in [
        "gqsa_requests_completed_total",
        "gqsa_tokens_generated_total",
        "gqsa_ttft_seconds_bucket",
        "gqsa_itl_seconds_bucket",
        "gqsa_queue_seconds_bucket",
        "gqsa_tick_seconds_bucket",
        "gqsa_spec_verify_walk_seconds_bucket",
    ] {
        assert!(text.contains(fam), "missing family {fam} in:\n{text}");
    }
    // per-shard labels survive the render
    assert!(text.contains("{shard=\"0\"}") && text.contains("{shard=\"1\"}"));
    // 6 completed requests across the fleet
    let total: f64 = text
        .lines()
        .filter(|l| l.starts_with("gqsa_requests_completed_total{"))
        .map(|l| l.split_whitespace().last().unwrap().parse::<f64>().unwrap())
        .sum();
    assert!((total - 6.0).abs() < 1e-9, "requests_completed {total} != 6");
}
