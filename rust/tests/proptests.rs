//! Property-based tests over coordinator + kernel invariants.
//!
//! proptest is not vendored in this offline image; `props!` drives each
//! property over many XorShift-seeded random cases with failing-seed
//! reporting — the same shrink-free discipline, in-tree.

use gqsa::coordinator::{Backend, EngineConfig, EngineCore, Request};
use gqsa::gqs::gemv::{gqs_gemv, gqs_gemv_ref};
use gqsa::gqs::gemv_dense::{QuantDense, Semi24Kernel};
use gqsa::gqs::layer::GqsLayer;
use gqsa::gqs::MatmulScratch;
use gqsa::model::config::ModelConfig;
use gqsa::model::transformer::LinearKind;
use gqsa::model::Transformer;
use gqsa::sparse::bsr::BsrMatrix;
use gqsa::sparse::group_prune::{group_prune, mask_from_scores};
use gqsa::sparse::saliency::SaliencyMetric;
use gqsa::sparse::semi24::{check_24, prune_24};
use gqsa::util::{Mat, XorShift};

/// Run `body(seed, rng)` for `n` random cases; panic reports the seed.
fn props(n: u64, mut body: impl FnMut(u64, &mut XorShift)) {
    for seed in 0..n {
        let mut rng = XorShift::new(seed * 7919 + 13);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            body(seed, &mut rng)
        }));
        if let Err(e) = result {
            eprintln!("property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

// ---------------------------------------------------------------------
// Kernel invariants
// ---------------------------------------------------------------------

#[test]
fn prop_gqs_gemv_opt_matches_ref() {
    props(40, |seed, rng| {
        let g = [4usize, 8, 16, 32][rng.below(4)];
        let ng = 1 + rng.below(8);
        let k = g * ng;
        let n = 1 + rng.below(60);
        let bits = [2u32, 4, 8][rng.below(3)];
        let sparsity = rng.next_f32() as f64 * 0.9;
        let w = Mat::randn(n, k, rng);
        let mask = group_prune(&w, None, SaliencyMetric::Magnitude, g, sparsity);
        let layer = GqsLayer::encode(&w, &mask, bits);
        let x = rng.normal_vec(k);
        let mut y1 = vec![0.0f32; n];
        let mut y2 = vec![0.0f32; n];
        let mut scratch = Vec::new();
        gqs_gemv_ref(&layer, &x, &mut y1);
        gqs_gemv(&layer, &x, &mut y2, &mut scratch);
        for i in 0..n {
            assert!(
                (y1[i] - y2[i]).abs() < 3e-3,
                "seed {seed} bits {bits} g {g}: row {i}: {} vs {}",
                y1[i],
                y2[i]
            );
        }
    });
}

#[test]
fn prop_matmul_equals_repeated_matvec() {
    // the tentpole invariant: LinearKind::matmul over X (T, K) equals T
    // independent matvec calls, for every kind / bit width / sparsity /
    // block size (the kernels replicate per-row op order, so the bound
    // is far tighter than the 1e-4 asserted here)
    props(30, |seed, rng| {
        let g = 16usize;
        let k = g * (1 + rng.below(6));
        let n = 2 * (1 + rng.below(20)); // even: Semi24 group alignment
        let t = [1usize, 3, 16][rng.below(3)];
        let bits = [2u32, 4, 8][rng.below(3)];
        let sparsity = [0.0, 0.5, 0.9][rng.below(3)];
        let w = Mat::randn(n, k, rng);
        let mask = group_prune(&w, None, SaliencyMetric::Magnitude, g, sparsity);
        let kinds = [
            LinearKind::Dense(w.clone()),
            LinearKind::Gqs(GqsLayer::encode(&w, &mask, bits)),
            LinearKind::QuantDense(QuantDense::encode(&w, bits, g)),
            LinearKind::Semi24(Semi24Kernel::encode(
                &prune_24(&w, None, SaliencyMetric::Magnitude),
                bits,
                g,
            )),
            LinearKind::BsrF32(BsrMatrix::encode(&w, &mask)),
        ];
        let x = Mat::randn(t, k, rng);
        let mut mm = MatmulScratch::new();
        for (ki, kind) in kinds.iter().enumerate() {
            let mut y = Mat::zeros(t, n);
            kind.matmul(&x, &mut y, &mut mm);
            let mut yr = vec![0.0f32; n];
            let mut sc = Vec::new();
            for ti in 0..t {
                kind.matvec(x.row(ti), &mut yr, &mut sc);
                for i in 0..n {
                    assert!(
                        (y.at(ti, i) - yr[i]).abs() < 1e-4,
                        "seed {seed} kind {ki} bits {bits} s {sparsity} t {ti} i {i}: {} vs {}",
                        y.at(ti, i),
                        yr[i]
                    );
                }
            }
        }
    });
}

#[test]
fn prop_bsr_roundtrip_equals_masked_dense() {
    props(40, |_, rng| {
        let g = [8usize, 16][rng.below(2)];
        let ng = 1 + rng.below(6);
        let n = 1 + rng.below(40);
        let w = Mat::randn(n, g * ng, rng);
        let scores = Mat::randn(n, ng, rng);
        let mask = mask_from_scores(&scores, g, rng.next_f32() as f64 * 0.9);
        let bsr = BsrMatrix::encode(&w, &mask);
        assert_eq!(bsr.decode().data, mask.apply(&w).data);
        let x = rng.normal_vec(g * ng);
        let y1 = bsr.matvec(&x);
        let y2 = mask.apply(&w).matvec(&x);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-3);
        }
    });
}

#[test]
fn prop_24_invariant_always_holds() {
    props(30, |_, rng| {
        let n = 1 + rng.below(30);
        let quads = 1 + rng.below(16);
        let w = Mat::randn(n, quads * 4, rng);
        for metric in [SaliencyMetric::Magnitude, SaliencyMetric::Wanda] {
            let h = {
                let x = Mat::randn(32, quads * 4, rng);
                x.transpose().matmul(&x)
            };
            let p = prune_24(&w, Some(&h), metric);
            assert!(check_24(&p));
        }
    });
}

#[test]
fn prop_group_mask_row_counts_exact() {
    props(50, |_, rng| {
        let n = 1 + rng.below(50);
        let ng = 1 + rng.below(32);
        let scores = Mat::randn(n, ng, rng);
        let s = rng.next_f32() as f64;
        let mask = mask_from_scores(&scores, 16, s);
        let expect = ((ng as f64 * (1.0 - s)).round() as usize).clamp(1, ng);
        for r in 0..n {
            assert_eq!(mask.kept_per_row(r), expect);
        }
    });
}

#[test]
fn prop_storage_monotone_in_sparsity() {
    props(20, |_, rng| {
        let w = Mat::randn(32, 128, rng);
        let s1 = rng.next_f32() as f64 * 0.5;
        let s2 = s1 + 0.3;
        let m1 = group_prune(&w, None, SaliencyMetric::Magnitude, 16, s1);
        let m2 = group_prune(&w, None, SaliencyMetric::Magnitude, 16, s2);
        let b1 = GqsLayer::encode(&w, &m1, 4).storage_bytes();
        let b2 = GqsLayer::encode(&w, &m2, 4).storage_bytes();
        assert!(b2 <= b1, "sparser must not be bigger: {b2} vs {b1}");
    });
}

// ---------------------------------------------------------------------
// Coordinator invariants (routing, batching, state)
// ---------------------------------------------------------------------

fn tiny_engine(rng: &mut XorShift, max_batch: usize) -> (EngineCore, ModelConfig) {
    let mut cfg = ModelConfig {
        family: "t".into(),
        vocab: 64,
        d_model: 32,
        n_layers: 1,
        n_heads: 2,
        d_ff: 48,
        max_seq: 128,
        pos: "rope".into(),
        act: "swiglu".into(),
        norm: "rmsnorm".into(),
        qkv_bias: false,
        tie_embeddings: true,
    };
    cfg.max_seq = 128;
    // random fp weights via public constructors
    let mut weights = std::collections::BTreeMap::new();
    let mat = |r: usize, c: usize, s: f32, rng: &mut XorShift| {
        let mut m = Mat::randn(r, c, rng);
        for v in &mut m.data {
            *v *= s;
        }
        m
    };
    weights.insert("tok_emb".into(), mat(64, 32, 0.05, rng));
    weights.insert("blk0.norm1".into(), Mat::from_vec(1, 32, vec![1.0; 32]));
    weights.insert("blk0.norm2".into(), Mat::from_vec(1, 32, vec![1.0; 32]));
    weights.insert("final_norm".into(), Mat::from_vec(1, 32, vec![1.0; 32]));
    for nm in ["attn.wq", "attn.wk", "attn.wv", "attn.wo"] {
        weights.insert(format!("blk0.{nm}"), mat(32, 32, 0.17, rng));
    }
    weights.insert("blk0.mlp.w1".into(), mat(48, 32, 0.17, rng));
    weights.insert("blk0.mlp.w2".into(), mat(48, 32, 0.17, rng));
    weights.insert("blk0.mlp.w3".into(), mat(32, 48, 0.14, rng));
    let fp = gqsa::gqs::format::FpModel { config: cfg.clone(), weights };
    let t = Transformer::from_fp(&fp).unwrap();
    let e = EngineCore::new(
        Backend::Native(t),
        &cfg,
        EngineConfig { max_batch, prefill_chunk: 4, kv_capacity: 128, ..Default::default() },
    )
    .unwrap();
    (e, cfg)
}

#[test]
fn prop_all_submitted_requests_complete_exactly_once() {
    props(12, |seed, rng| {
        let mb = 1 + rng.below(4);
        let (mut e, _) = tiny_engine(rng, mb);
        let n_req = 1 + rng.below(10) as u64;
        for i in 0..n_req {
            let plen = 1 + rng.below(12);
            let prompt: Vec<u32> = (0..plen).map(|_| rng.below(60) as u32).collect();
            e.submit(Request::new(i, prompt, 1 + rng.below(8)));
        }
        let out = e.run_to_completion().unwrap();
        let mut ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len() as u64, n_req, "seed {seed}: duplicate or lost requests");
        assert!(!e.has_work());
    });
}

#[test]
fn prop_generation_length_respects_bounds() {
    props(12, |_, rng| {
        let (mut e, _) = tiny_engine(rng, 2);
        let max_new = 1 + rng.below(12);
        for i in 0..4u64 {
            e.submit(Request::new(i, vec![1, 2, 3], max_new));
        }
        for r in e.run_to_completion().unwrap() {
            assert!(r.tokens.len() <= max_new);
            assert!(!r.tokens.is_empty());
        }
    });
}

#[test]
fn prop_batching_invariant_greedy_tokens_independent_of_batchmates() {
    props(6, |seed, rng| {
        let (mut solo, _) = tiny_engine(&mut XorShift::new(seed + 1000), 1);
        let prompt: Vec<u32> = (0..5).map(|_| rng.below(60) as u32).collect();
        solo.submit(Request::new(0, prompt.clone(), 6));
        let expected = solo.run_to_completion().unwrap()[0].tokens.clone();

        let (mut batched, _) = tiny_engine(&mut XorShift::new(seed + 1000), 4);
        batched.submit(Request::new(0, prompt, 6));
        for i in 1..4u64 {
            let p: Vec<u32> = (0..(1 + rng.below(8))).map(|_| rng.below(60) as u32).collect();
            batched.submit(Request::new(i, p, 6));
        }
        let out = batched.run_to_completion().unwrap();
        let got = &out.iter().find(|r| r.id == 0).unwrap().tokens;
        assert_eq!(got, &expected, "seed {seed}: batching changed tokens");
    });
}

#[test]
fn prop_timing_fields_consistent() {
    props(8, |_, rng| {
        let (mut e, _) = tiny_engine(rng, 2);
        e.submit(Request::new(0, vec![1; 6], 4));
        let out = e.run_to_completion().unwrap();
        let t = out[0].timing;
        assert!(t.total_us >= t.ttft_us);
        assert!(t.total_us >= t.queued_us + t.prefill_us);
    });
}

#[test]
fn prop_linear_kinds_agree_at_high_bits() {
    // At 8 bits / 0% sparsity, every LinearKind approximates dense well.
    props(10, |_, rng| {
        let w = Mat::randn(24, 64, rng);
        let x = rng.normal_vec(64);
        let mut y_dense = vec![0.0f32; 24];
        LinearKind::Dense(w.clone()).matvec(&x, &mut y_dense, &mut Vec::new());
        let mask = group_prune(&w, None, SaliencyMetric::Magnitude, 16, 0.0);
        let kinds = [
            LinearKind::Gqs(GqsLayer::encode(&w, &mask, 8)),
            LinearKind::QuantDense(gqsa::gqs::gemv_dense::QuantDense::encode(&w, 8, 16)),
            LinearKind::BsrF32(BsrMatrix::encode(&w, &mask)),
        ];
        for kind in kinds {
            let mut y = vec![0.0f32; 24];
            kind.matvec(&x, &mut y, &mut Vec::new());
            for i in 0..24 {
                assert!((y[i] - y_dense[i]).abs() < 0.12, "{} vs {}", y[i], y_dense[i]);
            }
        }
    });
}
