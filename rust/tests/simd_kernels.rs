//! Property tests for the runtime-dispatched SIMD microkernels: the
//! scalar path is the bit-exactness oracle (what `GQSA_SIMD=0` runs),
//! and the SIMD path implements the same canonical lane-structured
//! accumulation order — so every f32 kernel must match it BITWISE, for
//! every LinearKind, group size (including odd tails), and executor
//! chunk decomposition. The W4A8 integer path is a different numeric
//! (i8 activations), so it gets a bounded-error property plus exact
//! level-independence (i32 accumulation is associative).
//!
//! These tests mutate the process-global dispatch level through
//! `simd::force`, which would race the other tests in this binary, so
//! every test serializes through one poison-tolerant mutex. (The
//! library's unit tests never call `force`, so only this binary needs
//! the lock.)

use std::sync::{Arc, Mutex};

use gqsa::engine::executor::{Decomposition, ExecConfig, ExecScratch, Executor};
use gqsa::gqs::gemv::{gqs_gemv, gqs_gemv_i8, supports_i8};
use gqsa::gqs::gemv_dense::{dense_gemv, QuantDense};
use gqsa::gqs::layer::GqsLayer;
use gqsa::gqs::simd::{self, Simd};
use gqsa::model::config::demo_config;
use gqsa::model::sampler::argmax;
use gqsa::model::transformer::{random_fp, ExecHandle, Transformer};
use gqsa::model::{KvCache, Scratch};
use gqsa::quant::act::ActI8;
use gqsa::sparse::bsr::BsrMatrix;
use gqsa::sparse::group_prune::group_prune;
use gqsa::sparse::saliency::SaliencyMetric;
use gqsa::util::{Mat, XorShift};

static SIMD_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` with the dispatch level pinned to `level`, serialized
/// against every other forced region in this binary. Poison-tolerant:
/// a panicking test must not wedge the remaining ones.
fn with_level<R>(level: Simd, f: impl FnOnce() -> R) -> R {
    let _g = SIMD_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    simd::force(level);
    let r = f();
    simd::reset();
    r
}

fn forced(threads: usize, decomposition: Decomposition) -> Arc<Executor> {
    Executor::new(ExecConfig {
        threads,
        decomposition,
        chunks_per_lane: 1,
        min_units: 0,
        adaptive: false,
    })
}

#[test]
fn gqs_gemv_scalar_vs_simd_bitwise_across_bits_groups_and_tails() {
    // group sizes straddling the 8-lane chunk: 5 and 7 are pure tail,
    // 12 and 20 mix one/two chunks with a tail, 8/16/32 are chunk-even.
    let mut case = 0u64;
    for (bits, group) in
        [(4u32, 16usize), (4, 8), (4, 32), (4, 12), (4, 20), (8, 16), (8, 7), (2, 16), (2, 8), (4, 5)]
    {
        for sparsity in [0.0f64, 0.4, 0.8] {
            case += 1;
            let cols = 12 * group;
            let mut rng = XorShift::new(3_000 + case);
            let w = Mat::randn(40, cols, &mut rng);
            let mask = group_prune(&w, None, SaliencyMetric::Magnitude, group, sparsity);
            let layer = GqsLayer::encode(&w, &mask, bits);
            let x = rng.normal_vec(cols);

            let run = |level: Simd| {
                with_level(level, || {
                    let mut y = vec![0.0f32; 40];
                    let mut sc = Vec::new();
                    gqs_gemv(&layer, &x, &mut y, &mut sc);
                    y
                })
            };
            let scalar = run(Simd::Scalar);
            let vector = run(simd::best());
            assert_eq!(
                scalar, vector,
                "SIMD diverged from scalar oracle: w{bits} g{group} s{sparsity}"
            );
        }
    }
}

#[test]
fn dense_quant_and_bsr_kernels_scalar_vs_simd_bitwise() {
    let mut rng = XorShift::new(909);
    // odd col count: the dense dot runs 4 chunks + a 5-wide tail
    let w = Mat::randn(33, 37, &mut rng);
    let x = rng.normal_vec(37);
    let dense = || {
        let mut y = vec![0.0f32; 33];
        dense_gemv(&w, &x, &mut y);
        y
    };
    assert_eq!(
        with_level(Simd::Scalar, &dense),
        with_level(simd::best(), &dense),
        "dense f32 gemv diverged"
    );

    for (bits, group) in [(4u32, 16usize), (4, 12), (8, 7), (2, 16), (2, 8)] {
        let cols = 8 * group;
        let wq = Mat::randn(29, cols, &mut rng);
        let q = QuantDense::encode(&wq, bits, group);
        let xq = rng.normal_vec(cols);
        let run = |level: Simd| {
            with_level(level, || {
                let mut y = vec![0.0f32; 29];
                let mut sc = Vec::new();
                q.gemv(&xq, &mut y, &mut sc);
                y
            })
        };
        assert_eq!(run(Simd::Scalar), run(simd::best()), "quant-dense w{bits} g{group} diverged");
    }

    let wb = Mat::randn(31, 8 * 12, &mut rng);
    let mask = group_prune(&wb, None, SaliencyMetric::Magnitude, 12, 0.5);
    let bsr = BsrMatrix::encode(&wb, &mask);
    let xb = rng.normal_vec(8 * 12);
    let run = |level: Simd| {
        with_level(level, || {
            let mut y = vec![0.0f32; 31];
            bsr.matvec_into(&xb, &mut y);
            y
        })
    };
    assert_eq!(run(Simd::Scalar), run(simd::best()), "bsr f32 matvec diverged");
}

#[test]
fn executor_chunked_gemv_bitwise_scalar_vs_simd_threads_1_and_4() {
    // the chunk kernels the executor dispatches must hold the same
    // bitwise contract: (level, threads, decomposition) all free.
    let mut rng = XorShift::new(414);
    let group = 16usize;
    let cols = 20 * group;
    let w = Mat::randn(64, cols, &mut rng);
    let mask = group_prune(&w, None, SaliencyMetric::Magnitude, group, 0.5);
    let layer = GqsLayer::encode(&w, &mask, 4);
    let x = rng.normal_vec(cols);

    let mut outs = Vec::new();
    for level in [Simd::Scalar, simd::best()] {
        for threads in [1usize, 4] {
            for decomp in [Decomposition::StreamK, Decomposition::SliceK] {
                let y = with_level(level, || {
                    let exec = forced(threads, decomp);
                    let mut es = ExecScratch::default();
                    let mut gsum = Vec::new();
                    let mut y = vec![0.0f32; 64];
                    exec.gemv_gqs(&layer, &x, &mut y, &mut gsum, &mut es);
                    y
                });
                outs.push((level.name(), threads, decomp.name(), y));
            }
        }
    }
    let (ref_name, rt, rd, ref_y) = &outs[0];
    for (name, threads, decomp, y) in &outs[1..] {
        assert_eq!(
            y, ref_y,
            "{name}/t{threads}/{decomp} diverged from {ref_name}/t{rt}/{rd}"
        );
    }
}

#[test]
fn i8_path_bounded_error_and_exact_across_levels() {
    let mut case = 0u64;
    for (bits, group) in [(4u32, 16usize), (8, 16), (4, 8), (2, 16)] {
        assert!(supports_i8(bits, group), "w{bits} g{group} should support i8");
        case += 1;
        let cols = 10 * group;
        let mut rng = XorShift::new(5_000 + case);
        let w = Mat::randn(36, cols, &mut rng);
        let mask = group_prune(&w, None, SaliencyMetric::Magnitude, group, 0.5);
        let layer = GqsLayer::encode(&w, &mask, bits);
        let x = rng.normal_vec(cols);
        let mut act = ActI8::new();
        act.ensure(&x);
        act.ensure_asum(group);

        let run = |level: Simd| {
            with_level(level, || {
                let mut y = vec![0.0f32; 36];
                gqs_gemv_i8(&layer, &act, &mut y);
                y
            })
        };
        // i32 accumulation is associative: SIMD and scalar integer
        // kernels must agree EXACTLY, not just closely
        let scalar = run(Simd::Scalar);
        let vector = run(simd::best());
        assert_eq!(scalar, vector, "i8 kernel level-dependent: w{bits} g{group}");

        // bounded error vs the f32 kernel: each activation carries at
        // most scale/2 rounding error, so |Δy_r| <= s_a/2 * Σ|ŵ_r|
        let mut y_f32 = vec![0.0f32; 36];
        let mut sc = Vec::new();
        with_level(Simd::Scalar, || gqs_gemv(&layer, &x, &mut y_f32, &mut sc));
        let deq = layer.decode();
        for r in 0..36 {
            let wmass: f32 = deq.row(r).iter().map(|v| v.abs()).sum();
            let bound = act.scale * 0.5 * wmass + 1e-3;
            assert!(
                (scalar[r] - y_f32[r]).abs() <= bound,
                "w{bits} g{group} row {r}: |{} - {}| > {bound}",
                scalar[r],
                y_f32[r]
            );
        }
    }
}

fn tiny_models() -> (gqsa::model::ModelConfig, Vec<(&'static str, Transformer)>) {
    let mut cfg = demo_config();
    cfg.d_model = 64;
    cfg.n_layers = 2;
    cfg.n_heads = 2;
    cfg.d_ff = 96;
    cfg.vocab = 64;
    cfg.max_seq = 64;
    let fp = random_fp(&cfg, 23);
    let mut bsr_model = Transformer::from_fp(&fp).unwrap();
    let names: Vec<String> = bsr_model.linears.keys().cloned().collect();
    for name in names {
        let w = match bsr_model.linears.get(&name) {
            Some(gqsa::model::LinearKind::Dense(w)) => w.clone(),
            _ => continue,
        };
        let mask = group_prune(&w, None, SaliencyMetric::Magnitude, 16, 0.4);
        let b = BsrMatrix::encode(&w, &mask);
        bsr_model.linears.insert(name, gqsa::model::LinearKind::BsrF32(b));
    }
    let models = vec![
        ("dense", Transformer::from_fp(&fp).unwrap()),
        ("gqs", Transformer::from_fp_gqs_oneshot(&fp, None, 4, 16, 0.5).unwrap()),
        ("quant-dense", Transformer::from_fp_quantized(&fp, 4, 16).unwrap()),
        ("semi24", Transformer::from_fp_24(&fp, None, 4, 16).unwrap()),
        ("bsr-f32", bsr_model),
    ];
    (cfg, models)
}

#[test]
fn all_five_kinds_logits_bitwise_identical_scalar_vs_simd() {
    let (cfg, models) = tiny_models();
    let tokens = [3u32, 1, 4, 1, 5, 9];
    for (name, model) in &models {
        let run = |level: Simd| {
            with_level(level, || {
                let mut kv = KvCache::new(cfg.n_layers, cfg.n_heads, cfg.head_dim(), 32);
                let mut s = Scratch::new(&cfg);
                let mut logits = Vec::new();
                for &tok in &tokens {
                    model.decode_step(tok, &mut kv, &mut s).unwrap();
                    logits.push(s.logits.clone());
                }
                logits
            })
        };
        assert_eq!(
            run(Simd::Scalar),
            run(simd::best()),
            "{name}: SIMD forward diverged from the scalar oracle"
        );
    }
}

#[test]
fn greedy_decode_token_identical_across_levels_and_threads() {
    // the tentpole acceptance: greedy decode is token-identical with
    // GQSA_SIMD on/off (force(Scalar) is exactly the GQSA_SIMD=0
    // path), at 1 and 4 executor threads
    let (cfg, models) = tiny_models();
    for (name, model) in &models {
        let mut seqs: Vec<(String, Vec<u32>)> = Vec::new();
        for level in [Simd::Scalar, simd::best()] {
            for threads in [1usize, 4] {
                let toks = with_level(level, || {
                    let exec = forced(threads, Decomposition::StreamK);
                    let mut kv = KvCache::new(cfg.n_layers, cfg.n_heads, cfg.head_dim(), 64);
                    let mut s = Scratch::with_executor(&cfg, ExecHandle::with(exec));
                    for &tok in &[5u32, 6, 7] {
                        model.decode_step(tok, &mut kv, &mut s).unwrap();
                    }
                    let mut toks = Vec::new();
                    let mut last = argmax(&s.logits) as u32;
                    toks.push(last);
                    for _ in 0..12 {
                        model.decode_step(last, &mut kv, &mut s).unwrap();
                        last = argmax(&s.logits) as u32;
                        toks.push(last);
                    }
                    toks
                });
                seqs.push((format!("{}/t{threads}", level.name()), toks));
            }
        }
        let (ref_tag, ref_toks) = &seqs[0];
        for (tag, toks) in &seqs[1..] {
            assert_eq!(toks, ref_toks, "{name}: {tag} diverged from {ref_tag}");
        }
    }
}

#[test]
fn act_i8_forward_deterministic_across_levels() {
    // W4A8 model forward: not bitwise vs f32 (by design), but the
    // integer path itself must be level-independent — same logits under
    // the scalar and SIMD integer kernels.
    let (cfg, mut models) = tiny_models();
    for (_, model) in &mut models {
        model.act_i8 = true;
    }
    let tokens = [2u32, 7, 1, 8, 2, 8];
    for (name, m) in &models {
        let run = |level: Simd| {
            with_level(level, || {
                let mut kv = KvCache::new(cfg.n_layers, cfg.n_heads, cfg.head_dim(), 32);
                let mut s = Scratch::new(&cfg);
                let mut logits = Vec::new();
                for &tok in &tokens {
                    m.decode_step(tok, &mut kv, &mut s).unwrap();
                    logits.push(s.logits.clone());
                }
                logits
            })
        };
        assert_eq!(
            run(Simd::Scalar),
            run(simd::best()),
            "{name}: i8 forward level-dependent"
        );
    }
}
