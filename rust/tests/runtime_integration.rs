//! Integration: the PJRT runtime path (AOT HLO artifacts from jax) must
//! agree with the rust-native transformer on the same checkpoint — the
//! proof that all three layers compose.
//!
//! These tests require `make artifacts` to have run; they are skipped
//! (not failed) when artifacts are absent so `cargo test` works on a
//! fresh checkout.

use std::path::PathBuf;

#[cfg(feature = "pjrt")]
use gqsa::gqs::format::{FpModel, GqsModel};
#[cfg(feature = "pjrt")]
use gqsa::model::{KvCache, Scratch, Transformer};
#[cfg(feature = "pjrt")]
use gqsa::runtime::{Artifact, Runtime};

fn art() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

macro_rules! require {
    ($p:expr) => {
        if !$p.exists() {
            eprintln!("SKIP: {} missing (run `make artifacts`)", $p.display());
            return;
        }
    };
}

#[cfg(feature = "pjrt")]
#[test]
fn prefill_artifact_matches_native_forward() {
    let hlo = art().join("hlo");
    require!(hlo.join("tiny-llama.prefill16.hlo.txt"));
    require!(art().join("models/tiny-llama.fp.bin"));

    let rt = Runtime::cpu().expect("pjrt cpu client");
    let artf = rt.load(&hlo, "tiny-llama.prefill16").expect("load prefill");
    let fp = FpModel::load(art().join("models/tiny-llama.fp.bin")).unwrap();
    let native = Transformer::from_fp(&fp).unwrap();

    let tokens: Vec<u32> = b"hello gqsa test!".iter().map(|&b| u32::from(b)).collect();
    assert_eq!(tokens.len(), 16);

    // PJRT path
    let tok_i32: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
    let lit = Artifact::lit_i32(&tok_i32, &[16]).unwrap();
    let out = artf.run(vec![lit]).unwrap();
    let logits_pjrt = Artifact::to_vec_f32(&out[0]).unwrap();
    assert_eq!(logits_pjrt.len(), 16 * fp.config.vocab);

    // native path
    let logits_native = native.forward_all(&tokens).unwrap();

    let mut max_err = 0.0f32;
    for (a, b) in logits_pjrt.iter().zip(&logits_native.data) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(max_err < 2e-2, "pjrt vs native max err {max_err}");
}

#[cfg(feature = "pjrt")]
#[test]
fn decode_artifact_matches_native_decode() {
    let hlo = art().join("hlo");
    require!(hlo.join("tiny-llama.decode.hlo.txt"));
    require!(art().join("models/tiny-llama.fp.bin"));

    let rt = Runtime::cpu().unwrap();
    let artf = rt.load(&hlo, "tiny-llama.decode").unwrap();
    let fp = FpModel::load(art().join("models/tiny-llama.fp.bin")).unwrap();
    let native = Transformer::from_fp(&fp).unwrap();
    let cfg = &fp.config;

    let kv_spec = &artf.manifest.runtime_params[2];
    let kv_numel: usize = kv_spec.numel();

    let tokens = [104u32, 101, 108, 108, 111]; // "hello"
    let mut kv_lit = Artifact::lit_f32(&vec![0.0; kv_numel], &kv_spec.shape).unwrap();
    let mut kv_native = KvCache::new(cfg.n_layers, cfg.n_heads, cfg.head_dim(), 64);
    let mut scratch = Scratch::new(cfg);

    for (pos, &tok) in tokens.iter().enumerate() {
        let out = artf
            .run(vec![
                Artifact::lit_i32_scalar(tok as i32),
                Artifact::lit_i32_scalar(pos as i32),
                kv_lit,
            ])
            .unwrap();
        let logits_pjrt = Artifact::to_vec_f32(&out[0]).unwrap();
        let mut it = out.into_iter();
        let _ = it.next();
        kv_lit = it.next().unwrap();

        native.decode_step(tok, &mut kv_native, &mut scratch).unwrap();

        let mut max_err = 0.0f32;
        for (a, b) in logits_pjrt.iter().zip(&scratch.logits) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(max_err < 2e-2, "step {pos}: max err {max_err}");
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn gqs_decode_artifact_matches_native_gqs() {
    // The Pallas-kernel decode artifact vs the rust GQS engine on the
    // same compressed checkpoint — the paper's hot path through both
    // stacks.
    let hlo = art().join("hlo");
    require!(hlo.join("tiny-llama.decode_gqs.w4s50g16.hlo.txt"));
    require!(art().join("models/tiny-llama.w4s50g16.gqsa"));

    let rt = Runtime::cpu().unwrap();
    let artf = rt.load(&hlo, "tiny-llama.decode_gqs.w4s50g16").unwrap();
    let gm = GqsModel::load(art().join("models/tiny-llama.w4s50g16.gqsa")).unwrap();
    let native = Transformer::from_gqs(&gm).unwrap();
    let cfg = &gm.config;

    let kv_spec = &artf.manifest.runtime_params[2];
    let mut kv_lit = Artifact::lit_f32(&vec![0.0; kv_spec.numel()], &kv_spec.shape).unwrap();
    let mut kv_native = KvCache::new(cfg.n_layers, cfg.n_heads, cfg.head_dim(), 64);
    let mut scratch = Scratch::new(cfg);

    for (pos, &tok) in [116u32, 101, 32, 110, 97].iter().enumerate() {
        let out = artf
            .run(vec![
                Artifact::lit_i32_scalar(tok as i32),
                Artifact::lit_i32_scalar(pos as i32),
                kv_lit,
            ])
            .unwrap();
        let logits_pjrt = Artifact::to_vec_f32(&out[0]).unwrap();
        let mut it = out.into_iter();
        let _ = it.next();
        kv_lit = it.next().unwrap();

        native.decode_step(tok, &mut kv_native, &mut scratch).unwrap();

        let mut max_err = 0.0f32;
        for (a, b) in logits_pjrt.iter().zip(&scratch.logits) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(max_err < 5e-2, "step {pos}: max err {max_err}");
    }
}

#[test]
fn manifest_schema_sane() {
    let hlo = art().join("hlo");
    require!(hlo.join("tiny-llama.decode.manifest.json"));
    let m = gqsa::runtime::Manifest::load(&hlo.join("tiny-llama.decode.manifest.json")).unwrap();
    assert!(m.n_weight_inputs > 10);
    assert_eq!(m.runtime_params.len(), 3);
    assert_eq!(m.runtime_params[0].name, "token");
    assert_eq!(m.outputs.len(), 2);
}
