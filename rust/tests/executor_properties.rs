//! Property tests for the Stream-K parallel executor: across linear
//! kinds, bit widths, sparsities, group sizes, and 1–8 threads, the
//! parallel path must reproduce the sequential kernels bit for bit
//! (and therefore stay within the reference-kernel tolerance), and
//! greedy decode through a forced-parallel transformer must be
//! identical to the sequential forward.

use std::sync::Arc;

use gqsa::engine::executor::{Decomposition, ExecConfig, ExecScratch, Executor};
use gqsa::gqs::gemm::{gqs_gemm, MatmulScratch};
use gqsa::gqs::gemv::{gqs_gemv, gqs_gemv_ref};
use gqsa::gqs::layer::GqsLayer;
use gqsa::model::config::demo_config;
use gqsa::model::transformer::{random_fp, ExecHandle, Transformer};
use gqsa::model::{BlockScratch, KvCache, Scratch};
use gqsa::sparse::group_prune::group_prune;
use gqsa::sparse::saliency::SaliencyMetric;
use gqsa::util::{Mat, XorShift};

fn forced(threads: usize, decomposition: Decomposition) -> Arc<Executor> {
    Executor::new(ExecConfig {
        threads,
        decomposition,
        chunks_per_lane: 1,
        min_units: 0,
        adaptive: false,
    })
}

#[test]
fn executor_gemv_matches_sequential_and_ref_property_sweep() {
    // kinds x bits x sparsity x group x threads. Shapes straddling
    // packed bytes (g=5 @ 4-bit) exercise the sequential-fallback leg.
    let mut case = 0u64;
    for (bits, group) in [(4u32, 16usize), (4, 8), (4, 32), (8, 16), (2, 16), (2, 8), (4, 5)] {
        for sparsity in [0.0f64, 0.3, 0.6, 0.9] {
            case += 1;
            let cols = 16 * group;
            let mut rng = XorShift::new(1000 + case);
            let w = Mat::randn(56, cols, &mut rng);
            let mask = group_prune(&w, None, SaliencyMetric::Magnitude, group, sparsity);
            let layer = GqsLayer::encode(&w, &mask, bits);
            let x = rng.normal_vec(cols);

            let mut y_seq = vec![0.0f32; 56];
            let mut sc = Vec::new();
            gqs_gemv(&layer, &x, &mut y_seq, &mut sc);
            let mut y_ref = vec![0.0f32; 56];
            gqs_gemv_ref(&layer, &x, &mut y_ref);

            for threads in 1..=8usize {
                let exec = forced(threads, Decomposition::StreamK);
                let mut es = ExecScratch::default();
                let mut gsum = Vec::new();
                let mut y = vec![0.0f32; 56];
                exec.gemv_gqs(&layer, &x, &mut y, &mut gsum, &mut es);
                assert_eq!(
                    y, y_seq,
                    "parallel != sequential: w{bits} g{group} s{sparsity} threads {threads}"
                );
                for i in 0..56 {
                    assert!(
                        (y[i] - y_ref[i]).abs() < 2e-3,
                        "vs ref: w{bits} g{group} s{sparsity} threads {threads} @{i}"
                    );
                }
            }
        }
    }
}

#[test]
fn executor_gemm_matches_sequential_property_sweep() {
    for (bits, group, t) in [(4u32, 16usize, 1usize), (4, 16, 7), (8, 16, 3), (2, 8, 4), (4, 8, 2)]
    {
        let cols = 12 * group;
        let mut rng = XorShift::new(7_000 + bits as u64 * 10 + t as u64);
        let w = Mat::randn(44, cols, &mut rng);
        let mask = group_prune(&w, None, SaliencyMetric::Magnitude, group, 0.5);
        let layer = GqsLayer::encode(&w, &mask, bits);
        let x = Mat::randn(t, cols, &mut rng);

        let mut y_seq = Mat::zeros(t, 44);
        let mut mm = MatmulScratch::new();
        gqs_gemm(&layer, &x, &mut y_seq, &mut mm);

        for threads in [1usize, 2, 4, 8] {
            let exec = forced(threads, Decomposition::StreamK);
            let mut es = ExecScratch::default();
            let mut mm2 = MatmulScratch::new();
            let mut y = Mat::zeros(t, 44);
            exec.gemm_gqs(&layer, &x, &mut y, &mut mm2, &mut es);
            assert_eq!(y.data, y_seq.data, "w{bits} g{group} t{t} threads {threads}");
        }
    }
}

#[test]
fn all_linear_kinds_forward_bit_exact_under_forced_pool() {
    // model-level: every LinearKind variant routed through a forced
    // 4-lane pool produces logits identical to the sequential scratch.
    let mut cfg = demo_config();
    cfg.d_model = 64;
    cfg.n_layers = 2;
    cfg.n_heads = 2;
    cfg.d_ff = 96;
    cfg.vocab = 64;
    cfg.max_seq = 64;
    let fp = random_fp(&cfg, 5);
    // fifth kind: group-pruned unquantized BSR, built by swapping the
    // dense linears out of an fp model
    let mut bsr_model = Transformer::from_fp(&fp).unwrap();
    let names: Vec<String> = bsr_model.linears.keys().cloned().collect();
    for name in names {
        let w = match bsr_model.linears.get(&name) {
            Some(gqsa::model::LinearKind::Dense(w)) => w.clone(),
            _ => continue,
        };
        let mask = group_prune(&w, None, SaliencyMetric::Magnitude, 16, 0.4);
        let b = gqsa::sparse::bsr::BsrMatrix::encode(&w, &mask);
        bsr_model.linears.insert(name, gqsa::model::LinearKind::BsrF32(b));
    }
    let models: Vec<(&str, Transformer)> = vec![
        ("dense", Transformer::from_fp(&fp).unwrap()),
        ("gqs", Transformer::from_fp_gqs_oneshot(&fp, None, 4, 16, 0.5).unwrap()),
        ("quant-dense", Transformer::from_fp_quantized(&fp, 4, 16).unwrap()),
        ("semi24", Transformer::from_fp_24(&fp, None, 4, 16).unwrap()),
        ("bsr-f32", bsr_model),
    ];
    let tokens = [3u32, 1, 4, 1, 5, 9];
    for (name, model) in &models {
        // sequential per-token reference
        let mut kv = KvCache::new(cfg.n_layers, cfg.n_heads, cfg.head_dim(), 32);
        let mut s = Scratch::new(&cfg);
        let mut seq_logits = Vec::new();
        for &tok in &tokens {
            model.decode_step(tok, &mut kv, &mut s).unwrap();
            seq_logits.push(s.logits.clone());
        }
        // forced-parallel per-token path
        let exec = forced(4, Decomposition::StreamK);
        let mut kv_p = KvCache::new(cfg.n_layers, cfg.n_heads, cfg.head_dim(), 32);
        let mut sp = Scratch::with_executor(&cfg, ExecHandle::with(Arc::clone(&exec)));
        for (i, &tok) in tokens.iter().enumerate() {
            model.decode_step(tok, &mut kv_p, &mut sp).unwrap();
            assert_eq!(sp.logits, seq_logits[i], "{name} per-token step {i}");
        }
        // forced-parallel block path
        let mut kv_b = KvCache::new(cfg.n_layers, cfg.n_heads, cfg.head_dim(), 32);
        let mut bs =
            BlockScratch::with_executor(&cfg, tokens.len(), ExecHandle::with(Arc::clone(&exec)));
        model.forward_block(&tokens, &mut kv_b, &mut bs).unwrap();
        // the block kernels replicate per-token op order exactly, so the
        // parallel block path must match the sequential block path; and
        // within 1e-4 of the per-token chain (the PR-1 contract).
        let mut kv_b2 = KvCache::new(cfg.n_layers, cfg.n_heads, cfg.head_dim(), 32);
        let mut bs2 = BlockScratch::new(&cfg, tokens.len());
        model.forward_block(&tokens, &mut kv_b2, &mut bs2).unwrap();
        assert_eq!(bs.logits.data, bs2.logits.data, "{name} block parallel vs sequential");
        assert!(exec.stats().parallel_calls > 0, "{name}: pool never engaged");
    }
}

#[test]
fn greedy_decode_identical_threads_1_vs_4() {
    use gqsa::model::sampler::argmax;
    let mut cfg = demo_config();
    cfg.d_model = 64;
    cfg.n_layers = 1;
    cfg.n_heads = 2;
    cfg.d_ff = 96;
    cfg.vocab = 64;
    cfg.max_seq = 64;
    let fp = random_fp(&cfg, 17);
    let model = Transformer::from_fp_gqs_oneshot(&fp, None, 4, 16, 0.5).unwrap();
    let mut seqs = Vec::new();
    for threads in [1usize, 4] {
        let exec = forced(threads, Decomposition::StreamK);
        let mut kv = KvCache::new(cfg.n_layers, cfg.n_heads, cfg.head_dim(), 64);
        let mut s = Scratch::with_executor(&cfg, ExecHandle::with(exec));
        for &tok in &[5u32, 6, 7] {
            model.decode_step(tok, &mut kv, &mut s).unwrap();
        }
        let mut toks = Vec::new();
        let mut last = argmax(&s.logits) as u32;
        toks.push(last);
        for _ in 0..12 {
            model.decode_step(last, &mut kv, &mut s).unwrap();
            last = argmax(&s.logits) as u32;
            toks.push(last);
        }
        seqs.push(toks);
    }
    assert_eq!(seqs[0], seqs[1], "greedy decode diverged between 1 and 4 threads");
}
