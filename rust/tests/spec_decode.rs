//! Self-speculative decoding properties:
//!
//! * greedy speculative output is TOKEN-IDENTICAL to plain greedy
//!   decode on the same backend, across KV dtypes {f32, q8, q4} and
//!   executor thread counts {1, 4} — speculation changes latency,
//!   never content;
//! * KV pressure during drafting falls back cleanly to plain decode
//!   (same tokens, no errors, no leaked blocks);
//! * rejection-sampled (temperature) speculation completes and stays
//!   within the vocab;
//! * the fused fleet-verify schedule (`spec_batch`) is token-identical
//!   to the per-sequence schedule at concurrency {2, 8} across the
//!   same dtype/thread matrix, amortizing target walks, and a mixed
//!   fleet (speculating + plain + mid-prefill in one tick) completes
//!   with identical tokens.

use gqsa::coordinator::request::{SamplingCfg, SamplingMode};
use gqsa::coordinator::{Backend, EngineConfig, EngineCore, Request};
use gqsa::engine::executor::Decomposition;
use gqsa::model::config::demo_config;
use gqsa::model::transformer::random_fp;
use gqsa::model::{KvDtype, ModelConfig, Transformer};

fn cfg() -> ModelConfig {
    let mut cfg = demo_config();
    cfg.d_model = 64;
    cfg.n_layers = 2;
    cfg.n_heads = 2;
    cfg.d_ff = 96;
    cfg.vocab = 64;
    cfg.max_seq = 96;
    cfg
}

fn engine_n(
    spec_k: usize,
    kv_dtype: KvDtype,
    threads: usize,
    pool_blocks: usize,
    max_batch: usize,
    spec_batch: bool,
) -> EngineCore {
    let cfg = cfg();
    let fp = random_fp(&cfg, 2025);
    let t = Transformer::from_fp_gqs_oneshot(&fp, None, 4, 16, 0.5).unwrap();
    EngineCore::new(
        Backend::Native(t),
        &cfg,
        EngineConfig {
            max_batch,
            prefill_chunk: 6,
            kv_capacity: 96,
            kv_paged: true,
            kv_dtype,
            kv_pool_blocks: pool_blocks,
            threads,
            decomposition: Decomposition::StreamK,
            spec_k,
            spec_batch,
            ..Default::default()
        },
    )
    .unwrap()
}

fn engine(
    spec_k: usize,
    kv_dtype: KvDtype,
    threads: usize,
    pool_blocks: usize,
) -> EngineCore {
    engine_n(spec_k, kv_dtype, threads, pool_blocks, 3, false)
}

fn run_tokens(e: &mut EngineCore) -> Vec<Vec<u32>> {
    // mixed lengths: prompts and generations cross 16-position KV block
    // boundaries so speculative rollback exercises sealed blocks
    e.submit(Request::new(1, (0..20).map(|i| (i * 3 % 60) as u32).collect(), 30));
    e.submit(Request::new(2, vec![7, 11, 13], 25));
    e.submit(Request::new(3, vec![9; 18], 21));
    let mut out = e.run_to_completion().unwrap();
    out.sort_by_key(|r| r.id);
    out.into_iter().map(|r| r.tokens).collect()
}

#[test]
fn greedy_spec_identical_across_kv_dtypes_and_threads() {
    for dtype in [KvDtype::F32, KvDtype::Q8, KvDtype::Q4] {
        for threads in [1usize, 4] {
            let plain = run_tokens(&mut engine(0, dtype, threads, 0));
            let mut e = engine(4, dtype, threads, 0);
            let spec = run_tokens(&mut e);
            assert_eq!(
                plain, spec,
                "{dtype:?} threads={threads}: speculative greedy diverged from plain"
            );
            assert!(
                e.metrics.spec_rounds > 0,
                "{dtype:?} threads={threads}: speculation never engaged"
            );
            // (modulo blocks the shared-prefix cache keeps when the CI
            // leg enables it — cached retention is not a leak)
            let cached = e.prefix_cached_blocks();
            let s = e.kv_pool().unwrap().stats();
            assert_eq!(s.blocks_in_use, cached, "{dtype:?}: leaked KV blocks {s:?}");
            assert_eq!(
                s.allocs - s.frees,
                cached as u64,
                "{dtype:?}: alloc/free imbalance {s:?}"
            );
        }
    }
}

#[test]
fn cache_full_during_drafting_falls_back_to_plain_decode() {
    // a pool that fits the target comfortably but NOT target + draft:
    // the speculative path must shed the draft and finish plainly with
    // exactly the plain engine's tokens
    let pool_blocks = 8; // target peak: 2 layers * blocks_for(49) = 6
    let plain = {
        let mut e = engine(0, KvDtype::F32, 1, pool_blocks);
        e.submit(Request::new(1, (0..20).map(|i| (i % 60) as u32).collect(), 30));
        e.run_to_completion().unwrap()[0].clone()
    };
    let mut e = engine(4, KvDtype::F32, 1, pool_blocks);
    e.submit(Request::new(1, (0..20).map(|i| (i % 60) as u32).collect(), 30));
    let out = e.run_to_completion().unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].tokens, plain.tokens, "fallback path diverged from plain decode");
    assert_eq!(out[0].finish, plain.finish);
    assert!(
        e.metrics.spec_fallbacks > 0,
        "pool pressure never forced a speculative fallback"
    );
    assert_eq!(e.metrics.kv_evictions, 0, "fallback should not need evictions");
    let s = e.kv_pool().unwrap().stats();
    assert_eq!(s.blocks_in_use, e.prefix_cached_blocks(), "leaked KV blocks {s:?}");
}

#[test]
fn temperature_spec_decode_completes_with_rejection_sampling() {
    for mode in [SamplingMode::TopK, SamplingMode::TopP] {
        let mut e = engine(4, KvDtype::F32, 1, 0);
        for i in 0..3u64 {
            let mut req = Request::new(i, vec![(i as u32 % 50) + 2; 10], 20);
            req.sampling =
                SamplingCfg { mode, temperature: 0.8, top_k: 20, top_p: 0.9, ..Default::default() };
            e.submit(req);
        }
        let out = e.run_to_completion().unwrap();
        assert_eq!(out.len(), 3, "{mode:?}: requests dropped");
        for r in &out {
            assert_eq!(r.tokens.len(), 20, "{mode:?}: wrong length");
            assert!(r.tokens.iter().all(|&t| t < 64), "{mode:?}: token out of vocab");
        }
        assert!(e.metrics.spec_rounds > 0, "{mode:?}: speculation never engaged");
        let s = e.kv_pool().unwrap().stats();
        assert_eq!(s.blocks_in_use, e.prefix_cached_blocks(), "{mode:?}: leaked KV blocks");
    }
}

fn run_fleet(e: &mut EngineCore, c: usize) -> Vec<Vec<u32>> {
    // c concurrent requests with staggered prompt lengths and budgets,
    // all crossing KV block boundaries at some point
    for i in 0..c as u64 {
        let plen = 4 + (i as usize * 3) % 15;
        let prompt: Vec<u32> =
            (0..plen).map(|j| ((j as u64 * 7 + i * 13) % 60) as u32).collect();
        e.submit(Request::new(i, prompt, 14 + (i as usize % 5)));
    }
    let mut out = e.run_to_completion().unwrap();
    out.sort_by_key(|r| r.id);
    out.into_iter().map(|r| r.tokens).collect()
}

#[test]
fn batched_fleet_greedy_identical_to_per_seq_across_matrix() {
    // THE tentpole property test: at concurrency {2, 8} × KV dtypes
    // {f32, q8, q4} × executor threads {1, 4}, the fused fleet-verify
    // schedule emits exactly the per-sequence schedule's greedy tokens
    for c in [2usize, 8] {
        for dtype in [KvDtype::F32, KvDtype::Q8, KvDtype::Q4] {
            for threads in [1usize, 4] {
                let per = run_fleet(&mut engine_n(4, dtype, threads, 0, c, false), c);
                let mut e = engine_n(4, dtype, threads, 0, c, true);
                let fleet = run_fleet(&mut e, c);
                assert_eq!(
                    per, fleet,
                    "c={c} {dtype:?} threads={threads}: fleet verify diverged"
                );
                // the fused schedule really amortized target walks
                assert!(
                    e.metrics.spec_batch_rounds > 0,
                    "c={c} {dtype:?} threads={threads}: fleet path never engaged"
                );
                assert!(
                    e.metrics.spec_verify_walks < e.metrics.spec_rounds,
                    "c={c} {dtype:?}: walks={} not amortized over rounds={}",
                    e.metrics.spec_verify_walks,
                    e.metrics.spec_rounds
                );
                let s = e.kv_pool().unwrap().stats();
                assert_eq!(
                    s.blocks_in_use,
                    e.prefix_cached_blocks(),
                    "c={c} {dtype:?}: leaked KV blocks {s:?}"
                );
            }
        }
    }
}

#[test]
fn mixed_fleet_tick_speculating_plain_and_prefilling_together() {
    // one engine holds, simultaneously: speculating sequences, a
    // plain-decode sequence (spec opted out), and a sequence still
    // mid-prefill (45-token prompt at chunk 6 spans ~8 ticks). Tokens
    // must match the per-sequence schedule exactly, for everyone.
    let submit = |e: &mut EngineCore| {
        e.submit(Request::new(1, vec![5, 6, 7, 8], 18));
        e.submit(Request::new(2, vec![9, 10, 11], 16).with_spec_k(0));
        e.submit(Request::new(3, (0..45).map(|i| (i % 60) as u32).collect(), 12));
        e.submit(Request::new(4, vec![13; 7], 18));
        let mut out = e.run_to_completion().unwrap();
        out.sort_by_key(|r| r.id);
        out.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
    };
    let per = submit(&mut engine_n(4, KvDtype::F32, 1, 0, 4, false));
    let mut e = engine_n(4, KvDtype::F32, 1, 0, 4, true);
    let fleet = submit(&mut e);
    assert_eq!(per, fleet, "mixed fleet diverged from per-sequence schedule");
    assert_eq!(fleet.len(), 4);
    assert_eq!(fleet[0].len(), 18);
    assert_eq!(fleet[1].len(), 16);
    assert_eq!(fleet[2].len(), 12);
    assert_eq!(fleet[3].len(), 18);
    assert!(e.metrics.spec_batch_rounds > 0, "fleet path never engaged");
    assert!(e.metrics.spec_rounds > 0);
    let s = e.kv_pool().unwrap().stats();
    assert_eq!(s.blocks_in_use, e.prefix_cached_blocks(), "mixed fleet leaked blocks {s:?}");
}

#[test]
fn spec_with_slab_kv_matches_plain() {
    // rollback must also work on the legacy slab layout
    let mk = |spec_k: usize| {
        let cfg = cfg();
        let fp = random_fp(&cfg, 404);
        let t = Transformer::from_fp_gqs_oneshot(&fp, None, 4, 16, 0.5).unwrap();
        EngineCore::new(
            Backend::Native(t),
            &cfg,
            EngineConfig {
                max_batch: 2,
                prefill_chunk: 8,
                kv_capacity: 96,
                kv_paged: false,
                spec_k,
                ..Default::default()
            },
        )
        .unwrap()
    };
    let run = |e: &mut EngineCore| {
        e.submit(Request::new(1, vec![5, 9, 2, 7], 26));
        e.submit(Request::new(2, vec![11; 12], 15));
        let mut out = e.run_to_completion().unwrap();
        out.sort_by_key(|r| r.id);
        out.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
    };
    let plain = run(&mut mk(0));
    let mut e = mk(4);
    let spec = run(&mut e);
    assert_eq!(plain, spec, "slab speculative greedy diverged");
    assert!(e.metrics.spec_rounds > 0);
}
