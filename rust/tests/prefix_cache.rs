//! Shared-prefix KV cache properties:
//!
//! * a prefix HIT is bit-identical to a cold run: adopting published
//!   blocks and prefilling only the remainder produces byte-for-byte
//!   the logits of a from-scratch prefill — at paged-f32 trivially, and
//!   at q8/q4 because the adopted codes are the very codes the cold run
//!   would have sealed (deterministic quantization of bit-identical f32
//!   tails, with the adoption cap keeping the sealed-vs-tail storage
//!   state aligned with the lazy-seal rule);
//! * at engine level, flipping `prefix_cache` never changes a greedy
//!   token, across KV dtypes {f32, q8, q4} × executor threads {1, 4} ×
//!   speculation off/on — while the cache-on engine actually hits;
//! * cached-but-unreferenced blocks are reclaimed under pool pressure
//!   BEFORE admission blocks or live sequences are evicted.

use gqsa::coordinator::{Backend, EngineConfig, EngineCore, Request};
use gqsa::engine::executor::Decomposition;
use gqsa::model::config::demo_config;
use gqsa::model::kv_cache::blocks_for;
use gqsa::model::sampler::argmax;
use gqsa::model::transformer::random_fp;
use gqsa::model::{
    BlockScratch, KvBlockPool, KvCache, KvDtype, ModelConfig, Transformer, KV_BLOCK,
};
use gqsa::prefix::PrefixTree;

fn small_cfg() -> ModelConfig {
    let mut cfg = demo_config();
    cfg.d_model = 64;
    cfg.n_layers = 2;
    cfg.n_heads = 2;
    cfg.d_ff = 96;
    cfg.vocab = 64;
    cfg.max_seq = 160;
    cfg
}

/// Prefill `prompt` (16-aligned chunks so cold and hit runs share
/// chunk boundaries), then decode `n` greedy tokens; returns the logits
/// row of every computed position plus the greedy continuation.
fn run_with_adoption(
    model: &Transformer,
    kv: &mut KvCache,
    prompt: &[u32],
    adopted: usize, // positions already in kv via adopt_prefix
    n_decode: usize,
) -> (Vec<Vec<f32>>, Vec<u32>) {
    let mut bs = BlockScratch::new(&model.cfg, 16);
    let mut logits_rows: Vec<Vec<f32>> = Vec::new();
    for chunk in prompt[adopted..].chunks(16) {
        model.forward_block(chunk, kv, &mut bs).unwrap();
        for i in 0..chunk.len() {
            logits_rows.push(bs.logits.row(i).to_vec());
        }
    }
    let mut tokens = vec![argmax(logits_rows.last().unwrap()) as u32];
    for _ in 1..n_decode {
        let last = *tokens.last().unwrap();
        model.forward_block(&[last], kv, &mut bs).unwrap();
        logits_rows.push(bs.logits.row(0).to_vec());
        tokens.push(argmax(bs.logits.row(0)) as u32);
    }
    (logits_rows, tokens)
}

#[test]
fn prefix_hit_is_bit_identical_to_cold_run_across_dtypes() {
    let cfg = small_cfg();
    let fp = random_fp(&cfg, 77);
    let model = Transformer::from_fp(&fp).unwrap();
    let prompt: Vec<u32> = (0..(3 * KV_BLOCK + 5)).map(|i| ((i * 7 + 2) % 60) as u32).collect();
    for dtype in [KvDtype::F32, KvDtype::Q8, KvDtype::Q4] {
        let pool = KvBlockPool::new(cfg.n_heads, cfg.head_dim(), dtype, 64);
        let mut tree = PrefixTree::new(cfg.n_layers);

        // cold run: full prefill + decode, then publish prompt blocks
        let mut kv_cold = KvCache::paged(cfg.n_layers, &pool, 8 * KV_BLOCK);
        let (cold_logits, cold_tokens) =
            run_with_adoption(&model, &mut kv_cold, &prompt, 0, 8);
        let n_pub = (prompt.len() / KV_BLOCK).min(kv_cold.sealed_blocks_min());
        assert_eq!(n_pub, 3, "setup: expected 3 publishable blocks");
        tree.insert(&prompt, &kv_cold.share_prefix_blocks(n_pub));

        // hit run: adopt the longest cached chain, prefill the rest
        let hit = tree.lookup(&prompt, blocks_for(prompt.len()));
        assert_eq!(hit.len(), 3, "{dtype:?}: expected a full 3-block hit");
        let mut kv_hit = KvCache::paged(cfg.n_layers, &pool, 8 * KV_BLOCK);
        kv_hit.adopt_prefix(&hit);
        let adopted = hit.len() * KV_BLOCK;
        let (hit_logits, hit_tokens) =
            run_with_adoption(&model, &mut kv_hit, &prompt, adopted, 8);

        // BIT-identical: the hit run's logits for every position it
        // computes equal the cold run's rows for those same positions
        let skip = cold_logits.len() - hit_logits.len();
        assert_eq!(skip, adopted, "{dtype:?}: hit computed the wrong positions");
        for (i, (h, c)) in hit_logits.iter().zip(&cold_logits[skip..]).enumerate() {
            assert_eq!(h, c, "{dtype:?}: logits row {i} (pos {}) diverged", skip + i);
        }
        assert_eq!(cold_tokens, hit_tokens, "{dtype:?}: greedy continuation diverged");

        // teardown: everything recycles
        drop(kv_cold);
        drop(kv_hit);
        while tree.evict_lru() > 0 {}
        assert_eq!(pool.stats().blocks_in_use, 0, "{dtype:?}: leaked blocks");
    }
}

fn engine(
    prefix_cache: bool,
    kv_dtype: KvDtype,
    threads: usize,
    spec_k: usize,
    pool_blocks: usize,
) -> EngineCore {
    let cfg = small_cfg();
    let fp = random_fp(&cfg, 4040);
    let t = Transformer::from_fp_gqs_oneshot(&fp, None, 4, 16, 0.5).unwrap();
    EngineCore::new(
        Backend::Native(t),
        &cfg,
        EngineConfig {
            max_batch: 3,
            prefill_chunk: 8,
            kv_capacity: 144,
            kv_paged: true,
            kv_dtype,
            kv_pool_blocks: pool_blocks,
            threads,
            decomposition: Decomposition::StreamK,
            spec_k,
            prefix_cache,
            ..Default::default()
        },
    )
    .unwrap()
}

/// Shared-system-prompt workload: every request opens with the same
/// 48-token prefix, then a per-request tail — submitted twice so the
/// second wave hits what the first wave published.
fn run_workload(e: &mut EngineCore) -> Vec<Vec<u32>> {
    let system: Vec<u32> = (0..48).map(|i| ((i * 5 + 1) % 60) as u32).collect();
    let mut all = Vec::new();
    for wave in 0..2u64 {
        for i in 0..3u64 {
            let mut prompt = system.clone();
            prompt.extend((0..6).map(|j| ((i * 13 + j + wave) % 60) as u32));
            e.submit(Request::new(wave * 10 + i, prompt, 10));
        }
        let mut out = e.run_to_completion().unwrap();
        out.sort_by_key(|r| r.id);
        all.extend(out.into_iter().map(|r| r.tokens));
    }
    all
}

#[test]
fn cache_on_off_greedy_identity_across_dtypes_threads_and_spec() {
    for dtype in [KvDtype::F32, KvDtype::Q8, KvDtype::Q4] {
        for threads in [1usize, 4] {
            for spec_k in [0usize, 4] {
                let off = run_workload(&mut engine(false, dtype, threads, spec_k, 0));
                let mut e = engine(true, dtype, threads, spec_k, 0);
                let on = run_workload(&mut e);
                assert_eq!(
                    off, on,
                    "{dtype:?} threads={threads} spec_k={spec_k}: cache changed tokens"
                );
                let s = e.prefix_stats().unwrap();
                assert!(
                    s.hits > 0,
                    "{dtype:?} threads={threads} spec_k={spec_k}: cache never hit: {s:?}"
                );
                assert!(s.hit_positions > 0, "{s:?}");
                // reconcile: at idle, in_use is exactly what the cache holds
                let pool = e.kv_pool().unwrap();
                assert_eq!(
                    pool.stats().blocks_in_use,
                    e.prefix_cached_blocks(),
                    "{dtype:?} threads={threads} spec_k={spec_k}: leak"
                );
            }
        }
    }
}

#[test]
fn cache_eviction_yields_to_admission_under_pressure() {
    // a pool sized so the cache's retained blocks MUST be reclaimed for
    // the next (different-prompt) request to be admitted and finish:
    // the engine must serve it (evicting cached nodes), never deadlock,
    // and never have to evict the live sequence
    // 8 blocks: one 52-position request needs 6 (2 layers x 3), so the
    // 4 blocks the cache retains after request 1 force reclamation
    let mut e = engine(true, KvDtype::F32, 1, 0, 8);
    let p1: Vec<u32> = (0..40).map(|i| (i % 60) as u32).collect();
    e.submit(Request::new(1, p1, 12));
    e.run_to_completion().unwrap();
    let held = e.prefix_cached_blocks();
    assert!(held > 0, "first request published nothing");
    // second request with a DISJOINT prompt needs most of the pool
    let p2: Vec<u32> = (0..40).map(|i| ((i * 11 + 7) % 60) as u32).collect();
    e.submit(Request::new(2, p2, 12));
    let out = e.run_to_completion().unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].tokens.len(), 12, "request under cache pressure was truncated");
    let s = e.prefix_stats().unwrap();
    assert!(s.evicted_blocks > 0, "pressure never reclaimed cached blocks: {s:?}");
    assert_eq!(e.metrics.kv_evictions, 0, "live sequence evicted while cache held blocks");
}

#[test]
fn identical_concurrent_prompts_share_blocks_within_one_wave() {
    // two requests with the SAME prompt submitted together: the first
    // to retire publishes; a later wave shares. Within the batch both
    // run cold (admission happens before either retires) — tokens must
    // still be identical to the cache-off engine, and the pool's peak
    // must not exceed the off engine's (sharing never costs blocks)
    let prompt: Vec<u32> = (0..33).map(|i| ((i * 3 + 2) % 60) as u32).collect();
    let run = |on: bool| {
        let mut e = engine(on, KvDtype::Q8, 1, 0, 0);
        for i in 0..2u64 {
            e.submit(Request::new(i, prompt.clone(), 8));
        }
        let mut out = e.run_to_completion().unwrap();
        // second wave: same prompt again, now a guaranteed hit
        e.submit(Request::new(9, prompt.clone(), 8));
        out.extend(e.run_to_completion().unwrap());
        out.sort_by_key(|r| r.id);
        let peak = e.kv_pool().unwrap().stats().peak_in_use;
        let hits = e.prefix_stats().map_or(0, |s| s.hits);
        (out.into_iter().map(|r| r.tokens).collect::<Vec<_>>(), peak, hits)
    };
    let (off_tokens, off_peak, _) = run(false);
    let (on_tokens, on_peak, on_hits) = run(true);
    assert_eq!(off_tokens, on_tokens, "sharing changed tokens");
    assert!(on_hits > 0, "wave-2 request never hit");
    assert!(
        on_peak <= off_peak,
        "sharing increased peak block usage: {on_peak} > {off_peak}"
    );
}
