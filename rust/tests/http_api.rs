//! End-to-end: author a safetensors checkpoint on disk, serve it
//! through the full stack (import → encode → engine fleet → HTTP), and
//! drive it with a raw `TcpStream` client. The headline assertion is
//! the ISSUE's e2e proof: token ids streamed over SSE are byte-identical
//! to an in-process `Client` run against the same checkpoint.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;

use gqsa::ckpt::{load_transformer, write_fp, CkptEncode, CkptOptions};
use gqsa::coordinator::{Backend, EngineConfig, EngineCore, HttpServer, Request, Server};
use gqsa::model::config::demo_config;
use gqsa::model::transformer::random_fp;
use gqsa::util::Json;

/// Author a tiny checkpoint and bring up the whole stack on an
/// ephemeral port. The returned path is the on-disk checkpoint (the
/// caller removes it).
fn start_stack(tag: &str, seed: u64) -> (PathBuf, Server, HttpServer, SocketAddr) {
    let mut cfg = demo_config();
    cfg.d_model = 32;
    cfg.n_layers = 2;
    cfg.n_heads = 2;
    cfg.d_ff = 48;
    cfg.vocab = 48;
    cfg.max_seq = 96;
    let fp = random_fp(&cfg, seed);
    let path =
        std::env::temp_dir().join(format!("gqsa_http_{}_{}.safetensors", tag, std::process::id()));
    write_fp(&fp, &path).unwrap();

    let ckpt = path.clone();
    let srv = Server::start(move || {
        let opts = CkptOptions {
            encode: CkptEncode::Gqs { bits: 4, group: 16, sparsity: 0.5 },
            outlier_pct: gqsa::ckpt::outlier_pct_from_env(),
        };
        let (t, _report) = load_transformer(&ckpt, &opts)?;
        let cfg = t.cfg.clone();
        EngineCore::new(
            Backend::Native(t),
            &cfg,
            EngineConfig { max_batch: 4, prefill_chunk: 8, kv_capacity: 96, ..Default::default() },
        )
    });
    let http = HttpServer::bind("127.0.0.1:0", srv.client()).unwrap();
    let addr = http.local_addr();
    (path, srv, http, addr)
}

/// Minimal HTTP/1.1 client: send one request with `Connection: close`,
/// read to EOF, split status / body. Keep-alive flows drive the socket
/// directly with [`read_response`].
fn http(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    let body = body.unwrap_or("");
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).unwrap();
    let text = String::from_utf8_lossy(&raw).into_owned();
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .unwrap_or_else(|| panic!("no status line in: {text}"));
    let payload = text.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, payload)
}

/// Read one framed HTTP response off a kept-alive connection: status
/// line, headers (keeping the `Connection` header, lowercased), then
/// exactly `Content-Length` body bytes — never reads past the frame.
fn read_response(r: &mut impl BufRead) -> (u16, String, String) {
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .unwrap_or_else(|| panic!("no status line in: {line:?}"));
    let mut content_length = 0usize;
    let mut connection = String::new();
    loop {
        let mut h = String::new();
        r.read_line(&mut h).unwrap();
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap();
            } else if k.trim().eq_ignore_ascii_case("connection") {
                connection = v.trim().to_ascii_lowercase();
            }
        }
    }
    let mut body = vec![0u8; content_length];
    r.read_exact(&mut body).unwrap();
    (status, connection, String::from_utf8_lossy(&body).into_owned())
}

/// Parse an SSE payload: every `data:` frame before `[DONE]`, each as
/// parsed JSON. Panics if the stream is not `[DONE]`-terminated.
fn sse_frames(payload: &str) -> Vec<Json> {
    let mut frames = Vec::new();
    let mut done = false;
    for chunk in payload.split("\n\n") {
        let Some(data) = chunk.trim().strip_prefix("data: ") else { continue };
        if data == "[DONE]" {
            done = true;
            break;
        }
        frames.push(Json::parse(data).unwrap_or_else(|e| panic!("bad frame {data:?}: {e}")));
    }
    assert!(done, "stream not [DONE]-terminated: {payload:?}");
    frames
}

fn frame_choice(f: &Json) -> &Json {
    f.get("choices").and_then(|c| c.idx(0)).expect("frame has one choice")
}

#[test]
fn streamed_token_ids_byte_identical_to_in_process_client() {
    let (path, srv, http_srv, addr) = start_stack("sse", 31);

    // in-process reference run against the very same checkpoint.
    // vocab is 48, so prompts stick to bytes 32..48 (space/punctuation)
    let prompt_text = "(* !) #% &+,-.";
    let prompt: Vec<u32> = prompt_text.bytes().map(u32::from).collect();
    let reference = srv.client().generate(Request::new(7, prompt, 24)).unwrap();
    assert_eq!(reference.tokens.len(), 24);

    let body = Json::obj(vec![
        ("prompt", Json::str(prompt_text)),
        ("max_tokens", Json::num(24.0)),
        ("stream", Json::Bool(true)),
    ])
    .to_string();
    let (status, payload) = http(addr, "POST", "/v1/completions", Some(&body));
    assert_eq!(status, 200, "{payload}");

    let frames = sse_frames(&payload);
    let mut streamed = Vec::new();
    let mut finish = None;
    for f in &frames {
        let c = frame_choice(f);
        match c.get("token").and_then(Json::as_u64) {
            Some(t) => {
                let fr = c.get("finish_reason");
                assert!(fr.is_none() || fr == Some(&Json::Null), "delta frame carries a finish");
                streamed.push(t as u32);
            }
            None => finish = c.get("finish_reason").and_then(Json::as_str).map(str::to_string),
        }
    }
    assert_eq!(streamed, reference.tokens, "SSE token ids diverge from in-process run");
    assert_eq!(finish.as_deref(), Some("length"));

    http_srv.shutdown();
    srv.shutdown();
    std::fs::remove_file(&path).ok();
}

#[test]
fn stop_sequence_truncates_stream_and_reports_stop() {
    let (path, srv, http_srv, addr) = start_stack("stop", 37);

    // bytes 32..48 only: in-vocab for the 48-token model
    let prompt_text = "&* (!) -.";
    let prompt: Vec<u32> = prompt_text.bytes().map(u32::from).collect();
    let free = srv.client().generate(Request::new(9, prompt, 16)).unwrap();
    assert_eq!(free.tokens.len(), 16);
    // vocab is 48 so every token is a single ASCII byte — decodable
    // into a JSON stop string (Json::Display escapes control chars)
    let stop: Vec<u32> = free.tokens[2..4].to_vec();
    let stop_text: String = stop.iter().map(|&t| char::from(t as u8)).collect();
    // earliest point the free run's prefix ends with the stop sequence
    // (repeating tokens can complete it before index 3)
    let expect_end =
        (1..=free.tokens.len()).find(|&e| free.tokens[..e].ends_with(&stop)).unwrap();

    let body = Json::obj(vec![
        ("prompt", Json::str(prompt_text)),
        ("max_tokens", Json::num(16.0)),
        ("stream", Json::Bool(true)),
        ("stop", Json::str(stop_text)),
    ])
    .to_string();
    let (status, payload) = http(addr, "POST", "/v1/completions", Some(&body));
    assert_eq!(status, 200, "{payload}");

    let frames = sse_frames(&payload);
    let streamed: Vec<u32> = frames
        .iter()
        .filter_map(|f| frame_choice(f).get("token").and_then(Json::as_u64))
        .map(|t| t as u32)
        .collect();
    let finish = frames
        .iter()
        .filter_map(|f| frame_choice(f).get("finish_reason").and_then(Json::as_str))
        .last()
        .map(str::to_string);
    assert_eq!(
        streamed,
        free.tokens[..expect_end],
        "stop must halt exactly at the matching suffix"
    );
    assert_eq!(finish.as_deref(), Some("stop"));

    http_srv.shutdown();
    srv.shutdown();
    std::fs::remove_file(&path).ok();
}

#[test]
fn non_streaming_json_matches_in_process_and_counts_usage() {
    let (path, srv, http_srv, addr) = start_stack("json", 41);

    let prompt_text = "!#%+";
    let prompt: Vec<u32> = prompt_text.bytes().map(u32::from).collect();
    let reference = srv.client().generate(Request::new(3, prompt.clone(), 12)).unwrap();

    let body = Json::obj(vec![
        ("prompt", Json::str(prompt_text)),
        ("max_tokens", Json::num(12.0)),
        ("n", Json::num(2.0)),
    ])
    .to_string();
    let (status, payload) = http(addr, "POST", "/v1/completions", Some(&body));
    assert_eq!(status, 200, "{payload}");
    let j = Json::parse(&payload).unwrap();
    let choices = j.get("choices").and_then(Json::as_arr).unwrap();
    assert_eq!(choices.len(), 2);
    for (ci, c) in choices.iter().enumerate() {
        assert_eq!(c.get("index").and_then(Json::as_u64), Some(ci as u64));
        let ids: Vec<u32> = c
            .get("token_ids")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .filter_map(Json::as_u64)
            .map(|t| t as u32)
            .collect();
        // greedy: both choices and the in-process run are identical
        assert_eq!(ids, reference.tokens, "choice {ci}");
        assert_eq!(c.get("finish_reason").and_then(Json::as_str), Some("length"));
    }
    let usage = j.get("usage").unwrap();
    assert_eq!(usage.get("prompt_tokens").and_then(Json::as_u64), Some(prompt.len() as u64));
    assert_eq!(usage.get("completion_tokens").and_then(Json::as_u64), Some(24));

    http_srv.shutdown();
    srv.shutdown();
    std::fs::remove_file(&path).ok();
}

#[test]
fn report_route_and_error_paths() {
    let (path, srv, http_srv, addr) = start_stack("misc", 43);

    // a completed request shows up in the metrics text
    let body = Json::obj(vec![("prompt", Json::str("!!")), ("max_tokens", Json::num(4.0))])
        .to_string();
    let (status, _) = http(addr, "POST", "/v1/completions", Some(&body));
    assert_eq!(status, 200);
    let (status, report) = http(addr, "GET", "/report", None);
    assert_eq!(status, 200);
    assert!(report.contains("requests="), "not a metrics report: {report}");

    // malformed JSON body
    let (status, payload) = http(addr, "POST", "/v1/completions", Some("{not json"));
    assert_eq!(status, 400, "{payload}");
    assert!(payload.contains("invalid_request_error"));
    // missing prompt
    let (status, _) = http(addr, "POST", "/v1/completions", Some("{\"max_tokens\":4}"));
    assert_eq!(status, 400);
    // bad stop type
    let (status, _) =
        http(addr, "POST", "/v1/completions", Some("{\"prompt\":\"x\",\"stop\":7}"));
    assert_eq!(status, 400);
    // unknown route
    let (status, _) = http(addr, "GET", "/nope", None);
    assert_eq!(status, 404);

    http_srv.shutdown();
    srv.shutdown();
    std::fs::remove_file(&path).ok();
}

#[test]
fn keepalive_connection_serves_multiple_requests() {
    let (path, srv, http_srv, addr) = start_stack("keep", 47);

    let stream = TcpStream::connect(addr).unwrap();
    let mut out = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let body =
        Json::obj(vec![("prompt", Json::str("!#")), ("max_tokens", Json::num(3.0))]).to_string();
    for i in 0..3 {
        write!(
            out,
            "POST /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\nConnection: keep-alive\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        let (status, conn, payload) = read_response(&mut reader);
        assert_eq!(status, 200, "request {i}: {payload}");
        assert_eq!(conn, "keep-alive", "request {i} did not keep the connection");
        assert!(Json::parse(&payload).is_ok(), "request {i}: unparseable body");
    }
    // asking to close on the same socket ends it after the response
    write!(out, "GET /report HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n").unwrap();
    let (status, conn, report) = read_response(&mut reader);
    assert_eq!(status, 200);
    assert_eq!(conn, "close");
    assert!(report.contains("requests="));
    let mut rest = Vec::new();
    reader.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "server wrote past the closing response");

    // the front end counted the reuses: requests 2..4 rode a kept
    // socket (the one-shot /metrics scrape below does not)
    let (status, metrics) = http(addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    let reuses: f64 = metrics
        .lines()
        .find(|l| l.starts_with("gqsa_http_keepalive_reuses_total "))
        .and_then(|l| l.split_whitespace().last())
        .unwrap_or_else(|| panic!("no keepalive counter in:\n{metrics}"))
        .parse()
        .unwrap();
    assert!((reuses - 3.0).abs() < 1e-9, "expected 3 keep-alive reuses, saw {reuses}");

    http_srv.shutdown();
    srv.shutdown();
    std::fs::remove_file(&path).ok();
}

#[test]
fn metrics_route_serves_valid_prometheus_text() {
    let (path, srv, http_srv, addr) = start_stack("prom", 53);

    let body =
        Json::obj(vec![("prompt", Json::str("!#%")), ("max_tokens", Json::num(5.0))]).to_string();
    let (status, _) = http(addr, "POST", "/v1/completions", Some(&body));
    assert_eq!(status, 200);

    let (status, text) = http(addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    gqsa::obs::prom::validate(&text)
        .unwrap_or_else(|e| panic!("invalid Prometheus exposition: {e}\n{text}"));
    for fam in [
        "gqsa_requests_completed_total",
        "gqsa_tokens_generated_total",
        "gqsa_ttft_seconds_bucket",
        "gqsa_itl_seconds_bucket",
        "gqsa_queue_seconds_bucket",
        "gqsa_tick_seconds_bucket",
        "gqsa_spec_verify_walk_seconds_bucket",
        "gqsa_http_connections_total",
        "gqsa_http_requests_total",
    ] {
        assert!(text.contains(fam), "missing family {fam} in:\n{text}");
    }
    // the completion above landed on some shard of this stack
    let completed: f64 = text
        .lines()
        .filter(|l| l.starts_with("gqsa_requests_completed_total{"))
        .map(|l| l.split_whitespace().last().unwrap().parse::<f64>().unwrap())
        .sum();
    assert!(completed >= 1.0, "no completed requests in /metrics:\n{text}");

    http_srv.shutdown();
    srv.shutdown();
    std::fs::remove_file(&path).ok();
}

#[test]
fn trace_route_exports_chrome_json_spanning_http_and_engine() {
    let (path, srv, http_srv, addr) = start_stack("trace", 59);
    // force the recorder on for this stack (process-global and safe to
    // flip concurrently: tracing never changes tokens, and no other
    // test in this binary asserts on the span ring)
    gqsa::obs::force(true);
    gqsa::obs::clear();

    let body =
        Json::obj(vec![("prompt", Json::str("&*")), ("max_tokens", Json::num(4.0))]).to_string();
    let (status, _) = http(addr, "POST", "/v1/completions", Some(&body));
    assert_eq!(status, 200);
    let (status, text) = http(addr, "GET", "/trace", None);
    gqsa::obs::reset();
    assert_eq!(status, 200);

    let j = Json::parse(&text).unwrap_or_else(|e| panic!("trace JSON unparseable: {e}\n{text}"));
    let events = j.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    let has = |name: &str| {
        events.iter().any(|e| {
            e.get("ph").and_then(Json::as_str) == Some("X")
                && e.get("name").and_then(Json::as_str) == Some(name)
        })
    };
    // the request crossed the front end AND the engine: both layers
    // show up in one export
    assert!(has("http_completion"), "no http span in trace:\n{text}");
    assert!(has("engine_tick"), "no engine span in trace:\n{text}");
    assert!(
        events.iter().any(|e| e.get("ph").and_then(Json::as_str) == Some("M")),
        "no process_name metadata events"
    );

    http_srv.shutdown();
    srv.shutdown();
    std::fs::remove_file(&path).ok();
}

#[test]
fn logit_bias_steers_decoding_and_malformed_maps_are_400s() {
    let (path, srv, http_srv, addr) = start_stack("bias", 61);

    // +100 on token 33 ('!') dwarfs every logit this tiny model can
    // emit, so greedy decoding must pick it at every step
    let body = r#"{"prompt":"!#","max_tokens":6,"logit_bias":{"33":100}}"#;
    let (status, payload) = http(addr, "POST", "/v1/completions", Some(body));
    assert_eq!(status, 200, "{payload}");
    let j = Json::parse(&payload).unwrap();
    let ids: Vec<u64> = j
        .get("choices")
        .and_then(|c| c.idx(0))
        .and_then(|c| c.get("token_ids"))
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .filter_map(Json::as_u64)
        .collect();
    assert_eq!(ids, vec![33; 6], "bias +100 must pin every greedy pick to token 33");

    // malformed maps are typed 400s, not silent drops
    for bad in [
        r#"{"prompt":"x","logit_bias":[1,2]}"#,
        r#"{"prompt":"x","logit_bias":{"a":1}}"#,
        r#"{"prompt":"x","logit_bias":{"33":500}}"#,
    ] {
        let (status, payload) = http(addr, "POST", "/v1/completions", Some(bad));
        assert_eq!(status, 400, "{bad} -> {payload}");
        assert!(payload.contains("invalid_request_error"), "{payload}");
    }

    http_srv.shutdown();
    srv.shutdown();
    std::fs::remove_file(&path).ok();
}
