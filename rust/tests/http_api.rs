//! End-to-end: author a safetensors checkpoint on disk, serve it
//! through the full stack (import → encode → engine fleet → HTTP), and
//! drive it with a raw `TcpStream` client. The headline assertion is
//! the ISSUE's e2e proof: token ids streamed over SSE are byte-identical
//! to an in-process `Client` run against the same checkpoint.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;

use gqsa::ckpt::{load_transformer, write_fp, CkptEncode, CkptOptions};
use gqsa::coordinator::{Backend, EngineConfig, EngineCore, HttpServer, Request, Server};
use gqsa::model::config::demo_config;
use gqsa::model::transformer::random_fp;
use gqsa::util::Json;

/// Author a tiny checkpoint and bring up the whole stack on an
/// ephemeral port. The returned path is the on-disk checkpoint (the
/// caller removes it).
fn start_stack(tag: &str, seed: u64) -> (PathBuf, Server, HttpServer, SocketAddr) {
    let mut cfg = demo_config();
    cfg.d_model = 32;
    cfg.n_layers = 2;
    cfg.n_heads = 2;
    cfg.d_ff = 48;
    cfg.vocab = 48;
    cfg.max_seq = 96;
    let fp = random_fp(&cfg, seed);
    let path =
        std::env::temp_dir().join(format!("gqsa_http_{}_{}.safetensors", tag, std::process::id()));
    write_fp(&fp, &path).unwrap();

    let ckpt = path.clone();
    let srv = Server::start(move || {
        let opts = CkptOptions {
            encode: CkptEncode::Gqs { bits: 4, group: 16, sparsity: 0.5 },
            outlier_pct: gqsa::ckpt::outlier_pct_from_env(),
        };
        let (t, _report) = load_transformer(&ckpt, &opts)?;
        let cfg = t.cfg.clone();
        EngineCore::new(
            Backend::Native(t),
            &cfg,
            EngineConfig { max_batch: 4, prefill_chunk: 8, kv_capacity: 96, ..Default::default() },
        )
    });
    let http = HttpServer::bind("127.0.0.1:0", srv.client()).unwrap();
    let addr = http.local_addr();
    (path, srv, http, addr)
}

/// Minimal HTTP/1.1 client: send one request, read to EOF (the server
/// closes every connection), split status / body.
fn http(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    let body = body.unwrap_or("");
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).unwrap();
    let text = String::from_utf8_lossy(&raw).into_owned();
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .unwrap_or_else(|| panic!("no status line in: {text}"));
    let payload = text.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, payload)
}

/// Parse an SSE payload: every `data:` frame before `[DONE]`, each as
/// parsed JSON. Panics if the stream is not `[DONE]`-terminated.
fn sse_frames(payload: &str) -> Vec<Json> {
    let mut frames = Vec::new();
    let mut done = false;
    for chunk in payload.split("\n\n") {
        let Some(data) = chunk.trim().strip_prefix("data: ") else { continue };
        if data == "[DONE]" {
            done = true;
            break;
        }
        frames.push(Json::parse(data).unwrap_or_else(|e| panic!("bad frame {data:?}: {e}")));
    }
    assert!(done, "stream not [DONE]-terminated: {payload:?}");
    frames
}

fn frame_choice(f: &Json) -> &Json {
    f.get("choices").and_then(|c| c.idx(0)).expect("frame has one choice")
}

#[test]
fn streamed_token_ids_byte_identical_to_in_process_client() {
    let (path, srv, http_srv, addr) = start_stack("sse", 31);

    // in-process reference run against the very same checkpoint.
    // vocab is 48, so prompts stick to bytes 32..48 (space/punctuation)
    let prompt_text = "(* !) #% &+,-.";
    let prompt: Vec<u32> = prompt_text.bytes().map(u32::from).collect();
    let reference = srv.client().generate(Request::new(7, prompt, 24)).unwrap();
    assert_eq!(reference.tokens.len(), 24);

    let body = Json::obj(vec![
        ("prompt", Json::str(prompt_text)),
        ("max_tokens", Json::num(24.0)),
        ("stream", Json::Bool(true)),
    ])
    .to_string();
    let (status, payload) = http(addr, "POST", "/v1/completions", Some(&body));
    assert_eq!(status, 200, "{payload}");

    let frames = sse_frames(&payload);
    let mut streamed = Vec::new();
    let mut finish = None;
    for f in &frames {
        let c = frame_choice(f);
        match c.get("token").and_then(Json::as_u64) {
            Some(t) => {
                let fr = c.get("finish_reason");
                assert!(fr.is_none() || fr == Some(&Json::Null), "delta frame carries a finish");
                streamed.push(t as u32);
            }
            None => finish = c.get("finish_reason").and_then(Json::as_str).map(str::to_string),
        }
    }
    assert_eq!(streamed, reference.tokens, "SSE token ids diverge from in-process run");
    assert_eq!(finish.as_deref(), Some("length"));

    http_srv.shutdown();
    srv.shutdown();
    std::fs::remove_file(&path).ok();
}

#[test]
fn stop_sequence_truncates_stream_and_reports_stop() {
    let (path, srv, http_srv, addr) = start_stack("stop", 37);

    // bytes 32..48 only: in-vocab for the 48-token model
    let prompt_text = "&* (!) -.";
    let prompt: Vec<u32> = prompt_text.bytes().map(u32::from).collect();
    let free = srv.client().generate(Request::new(9, prompt, 16)).unwrap();
    assert_eq!(free.tokens.len(), 16);
    // vocab is 48 so every token is a single ASCII byte — decodable
    // into a JSON stop string (Json::Display escapes control chars)
    let stop: Vec<u32> = free.tokens[2..4].to_vec();
    let stop_text: String = stop.iter().map(|&t| char::from(t as u8)).collect();
    // earliest point the free run's prefix ends with the stop sequence
    // (repeating tokens can complete it before index 3)
    let expect_end =
        (1..=free.tokens.len()).find(|&e| free.tokens[..e].ends_with(&stop)).unwrap();

    let body = Json::obj(vec![
        ("prompt", Json::str(prompt_text)),
        ("max_tokens", Json::num(16.0)),
        ("stream", Json::Bool(true)),
        ("stop", Json::str(stop_text)),
    ])
    .to_string();
    let (status, payload) = http(addr, "POST", "/v1/completions", Some(&body));
    assert_eq!(status, 200, "{payload}");

    let frames = sse_frames(&payload);
    let streamed: Vec<u32> = frames
        .iter()
        .filter_map(|f| frame_choice(f).get("token").and_then(Json::as_u64))
        .map(|t| t as u32)
        .collect();
    let finish = frames
        .iter()
        .filter_map(|f| frame_choice(f).get("finish_reason").and_then(Json::as_str))
        .last()
        .map(str::to_string);
    assert_eq!(
        streamed,
        free.tokens[..expect_end],
        "stop must halt exactly at the matching suffix"
    );
    assert_eq!(finish.as_deref(), Some("stop"));

    http_srv.shutdown();
    srv.shutdown();
    std::fs::remove_file(&path).ok();
}

#[test]
fn non_streaming_json_matches_in_process_and_counts_usage() {
    let (path, srv, http_srv, addr) = start_stack("json", 41);

    let prompt_text = "!#%+";
    let prompt: Vec<u32> = prompt_text.bytes().map(u32::from).collect();
    let reference = srv.client().generate(Request::new(3, prompt.clone(), 12)).unwrap();

    let body = Json::obj(vec![
        ("prompt", Json::str(prompt_text)),
        ("max_tokens", Json::num(12.0)),
        ("n", Json::num(2.0)),
    ])
    .to_string();
    let (status, payload) = http(addr, "POST", "/v1/completions", Some(&body));
    assert_eq!(status, 200, "{payload}");
    let j = Json::parse(&payload).unwrap();
    let choices = j.get("choices").and_then(Json::as_arr).unwrap();
    assert_eq!(choices.len(), 2);
    for (ci, c) in choices.iter().enumerate() {
        assert_eq!(c.get("index").and_then(Json::as_u64), Some(ci as u64));
        let ids: Vec<u32> = c
            .get("token_ids")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .filter_map(Json::as_u64)
            .map(|t| t as u32)
            .collect();
        // greedy: both choices and the in-process run are identical
        assert_eq!(ids, reference.tokens, "choice {ci}");
        assert_eq!(c.get("finish_reason").and_then(Json::as_str), Some("length"));
    }
    let usage = j.get("usage").unwrap();
    assert_eq!(usage.get("prompt_tokens").and_then(Json::as_u64), Some(prompt.len() as u64));
    assert_eq!(usage.get("completion_tokens").and_then(Json::as_u64), Some(24));

    http_srv.shutdown();
    srv.shutdown();
    std::fs::remove_file(&path).ok();
}

#[test]
fn report_route_and_error_paths() {
    let (path, srv, http_srv, addr) = start_stack("misc", 43);

    // a completed request shows up in the metrics text
    let body = Json::obj(vec![("prompt", Json::str("!!")), ("max_tokens", Json::num(4.0))])
        .to_string();
    let (status, _) = http(addr, "POST", "/v1/completions", Some(&body));
    assert_eq!(status, 200);
    let (status, report) = http(addr, "GET", "/report", None);
    assert_eq!(status, 200);
    assert!(report.contains("requests="), "not a metrics report: {report}");

    // malformed JSON body
    let (status, payload) = http(addr, "POST", "/v1/completions", Some("{not json"));
    assert_eq!(status, 400, "{payload}");
    assert!(payload.contains("invalid_request_error"));
    // missing prompt
    let (status, _) = http(addr, "POST", "/v1/completions", Some("{\"max_tokens\":4}"));
    assert_eq!(status, 400);
    // bad stop type
    let (status, _) =
        http(addr, "POST", "/v1/completions", Some("{\"prompt\":\"x\",\"stop\":7}"));
    assert_eq!(status, 400);
    // unknown route
    let (status, _) = http(addr, "GET", "/nope", None);
    assert_eq!(status, 404);

    http_srv.shutdown();
    srv.shutdown();
    std::fs::remove_file(&path).ok();
}
