//! Integration: the full serving stack (server thread + continuous
//! batching) on the real compressed artifacts, including the PJRT
//! backend. Artifact-dependent tests skip on fresh checkouts.

use std::path::PathBuf;

use gqsa::bench::Workbench;
#[cfg(feature = "pjrt")]
use gqsa::coordinator::backend::PjrtBackend;
use gqsa::coordinator::{Backend, EngineConfig, EngineCore, Request, Server};
#[cfg(feature = "pjrt")]
use gqsa::runtime::Runtime;

fn art() -> PathBuf {
    Workbench::default_dir()
}

macro_rules! require {
    ($p:expr) => {
        if !$p.exists() {
            eprintln!("SKIP: {} missing (run `make artifacts`)", $p.display());
            return;
        }
    };
}

#[test]
fn serve_gqsa_model_end_to_end() {
    require!(art().join("models/tiny-llama.w4s50g16.gqsa"));
    let srv = Server::start(|| {
        let mut wb = Workbench::new(art());
        let model = wb.variant("tiny-llama", "gqsa:w4s50g16")?;
        let cfg = model.cfg.clone();
        EngineCore::new(
            Backend::Native(model),
            &cfg,
            EngineConfig { max_batch: 3, prefill_chunk: 8, kv_capacity: 128, ..Default::default() },
        )
    });
    let mut handles = Vec::new();
    for i in 0..6u64 {
        let c = srv.client();
        handles.push(std::thread::spawn(move || {
            let prompt: Vec<u32> = b"the ".iter().map(|&b| u32::from(b)).collect();
            c.generate(Request::new(i, prompt, 24))
        }));
    }
    for h in handles {
        let resp = h.join().unwrap().unwrap();
        assert_eq!(resp.tokens.len(), 24);
        assert!(resp.tokens.iter().all(|&t| t < 256));
        assert!(resp.timing.ttft_us > 0);
    }
    let report = srv.client().metrics_report().unwrap();
    assert!(report.contains("requests=6"), "{report}");
    srv.shutdown();
}

#[test]
fn greedy_output_identical_native_all_sparsities() {
    // identical prompts through different compression levels should all
    // produce in-vocab tokens and deterministic output per model
    require!(art().join("models/tiny-llama.w4s20g16.gqsa"));
    let mut wb = Workbench::new(art());
    for tag in ["w4s20g16", "w4s50g16"] {
        let model = wb.variant("tiny-llama", &format!("gqsa:{tag}")).unwrap();
        let cfg = model.cfg.clone();
        let run = |m: gqsa::model::Transformer| {
            let mut e = EngineCore::new(
                Backend::Native(m),
                &cfg,
                EngineConfig { max_batch: 1, prefill_chunk: 8, kv_capacity: 64, ..Default::default() },
            )
            .unwrap();
            e.submit(Request::new(0, vec![116, 104, 101, 32], 16));
            e.run_to_completion().unwrap()[0].tokens.clone()
        };
        let a = run(model);
        let model2 = wb.variant("tiny-llama", &format!("gqsa:{tag}")).unwrap();
        let b = run(model2);
        assert_eq!(a, b, "{tag}: nondeterministic");
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_backend_serves_requests() {
    require!(art().join("hlo/tiny-llama.decode.hlo.txt"));
    require!(art().join("models/tiny-llama.fp.bin"));
    let srv = Server::start(|| {
        let rt = Runtime::cpu()?;
        let artifact = rt.load(art().join("hlo"), "tiny-llama.decode")?;
        let wb = Workbench::new(art());
        let cfg = wb.fp("tiny-llama")?.config.clone();
        EngineCore::new(
            Backend::Pjrt(PjrtBackend::new(artifact)?),
            &cfg,
            EngineConfig { max_batch: 2, prefill_chunk: 8, kv_capacity: 64, ..Default::default() },
        )
    });
    let c = srv.client();
    let resp = c
        .generate(Request::new(0, vec![116, 104, 101, 32], 8))
        .unwrap();
    assert_eq!(resp.tokens.len(), 8);
    srv.shutdown();
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_and_native_agree_on_greedy_tokens() {
    // the strongest composition check: same checkpoint, two compute
    // stacks, identical greedy decodes
    require!(art().join("hlo/tiny-llama.decode.hlo.txt"));
    let mut wb = Workbench::new(art());
    let cfg = wb.fp("tiny-llama").unwrap().config.clone();
    let prompt = vec![116u32, 104, 101, 32];

    let native_tokens = {
        let model = wb.variant("tiny-llama", "fp").unwrap();
        let mut e = EngineCore::new(
            Backend::Native(model),
            &cfg,
            EngineConfig { max_batch: 1, prefill_chunk: 8, kv_capacity: 64, ..Default::default() },
        )
        .unwrap();
        e.submit(Request::new(0, prompt.clone(), 12));
        e.run_to_completion().unwrap()[0].tokens.clone()
    };

    let pjrt_tokens = {
        let rt = Runtime::cpu().unwrap();
        let artifact = rt.load(art().join("hlo"), "tiny-llama.decode").unwrap();
        let mut e = EngineCore::new(
            Backend::Pjrt(PjrtBackend::new(artifact).unwrap()),
            &cfg,
            EngineConfig { max_batch: 1, prefill_chunk: 8, kv_capacity: 64, ..Default::default() },
        )
        .unwrap();
        e.submit(Request::new(0, prompt, 12));
        e.run_to_completion().unwrap()[0].tokens.clone()
    };

    assert_eq!(native_tokens, pjrt_tokens, "greedy tokens diverge across stacks");
}
