//! Bench: Slice-K vs Stream-K scheduling on the multi-SM simulator
//! (Figure 5 / Appendix I shape) + wall-clock of the schedulers
//! themselves. `cargo bench --bench engine_schedulers`.

use gqsa::bench::Bench;
use gqsa::engine::cost_model::{CostModel, GpuSpec};
use gqsa::engine::{simulate, slice_k, stream_k, Workload};

fn main() {
    let cm = CostModel::new(GpuSpec::default());
    println!("# scheduler comparison (simulated cycles; util in parens)");
    for (label, hot, skew) in [
        ("uniform", 0.0, 1.0),
        ("skew 10% x4", 0.10, 4.0),
        ("skew 5% x16", 0.05, 16.0),
        ("skew 3% x32", 0.03, 32.0),
    ] {
        let wl = Workload::synthetic(4096, 8, hot, skew, 5);
        let slice = simulate(&slice_k::decompose(&wl, 8), &cm);
        let stream = simulate(
            &stream_k::decompose(&wl, stream_k::default_cta_count(cm.spec.n_sm, 4)),
            &cm,
        );
        println!(
            "{label:<14} slice {:>12.0} ({:.2})   stream {:>12.0} ({:.2})   speedup {:.2}x",
            slice.makespan,
            slice.utilization,
            stream.makespan,
            stream.utilization,
            slice.makespan / stream.makespan
        );
    }

    // decomposition overhead itself (host-side cost of the scheduler)
    let wl = Workload::synthetic(4096, 8, 0.05, 16.0, 5);
    let r1 = Bench::new("slice_k::decompose").run(|| {
        std::hint::black_box(slice_k::decompose(&wl, 8));
    });
    let r2 = Bench::new("stream_k::decompose").run(|| {
        std::hint::black_box(stream_k::decompose(&wl, 432));
    });
    println!("{}", r1.report());
    println!("{}", r2.report());
}
