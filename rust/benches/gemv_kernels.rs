//! Bench: GEMV kernels (Figure 6). Run via `cargo bench --bench gemv_kernels`.
//!
//! Criterion is not vendored in this offline image; the in-tree harness
//! (gqsa::bench::Bench) provides warmup + timed iterations. Ratios
//! between kernels are the reproduction target.

use gqsa::bench::Bench;
use gqsa::gqs::gemv::{gqs_gemv, gqs_gemv_ref};
use gqsa::gqs::gemv_dense::{dense_gemv, QuantDense, Semi24Kernel};
use gqsa::gqs::layer::GqsLayer;
use gqsa::sparse::group_prune::group_prune;
use gqsa::sparse::saliency::SaliencyMetric;
use gqsa::sparse::semi24::prune_24;
use gqsa::util::{Mat, XorShift};

fn main() {
    let (n, k) = (1024usize, 1024usize);
    let mut rng = XorShift::new(42);
    let w = Mat::randn(n, k, &mut rng);
    let x = rng.normal_vec(k);
    let mut y = vec![0.0f32; n];
    let mut scratch: Vec<f32> = Vec::new();

    println!("# GEMV kernel bench ({n}x{k}) — Figure 6 shape");

    let r_fp = Bench::new("fp32 dense").run(|| dense_gemv(&w, &x, &mut y));
    println!("{}", r_fp.report());

    for bits in [8u32, 4, 2] {
        let qd = QuantDense::encode(&w, bits, 16);
        let r = Bench::new(format!("w{bits} dense (fused dequant)")).run(|| {
            qd.gemv(&x, &mut y, &mut scratch)
        });
        println!("{}", r.report());
    }

    let w24 = prune_24(&w, None, SaliencyMetric::Magnitude);
    let k24 = Semi24Kernel::encode(&w24, 4, 16);
    let r_24 = Bench::new("w4 2:4 (metadata kernel)").run(|| k24.gemv(&x, &mut y));
    println!("{}", r_24.report());

    for s in [0.3f64, 0.5, 0.7] {
        let mask = group_prune(&w, None, SaliencyMetric::Magnitude, 16, s);
        let layer = GqsLayer::encode(&w, &mask, 4);
        let r = Bench::new(format!("GQS w4 s{:.0}% g16 (opt)", s * 100.0))
            .run(|| gqs_gemv(&layer, &x, &mut y, &mut scratch));
        println!("{}  [{:.2}x vs 2:4]", r.report(), r_24.mean_us() / r.mean_us());
        if s == 0.5 {
            let r_ref = Bench::new("GQS w4 s50% g16 (scalar ref)")
                .run(|| gqs_gemv_ref(&layer, &x, &mut y));
            println!(
                "{}  [opt speedup {:.2}x]",
                r_ref.report(),
                r_ref.mean_us() / r.mean_us()
            );
        }
    }

    for g in [8usize, 32, 128] {
        let mask = group_prune(&w, None, SaliencyMetric::Magnitude, g, 0.5);
        let layer = GqsLayer::encode(&w, &mask, 4);
        let r = Bench::new(format!("GQS w4 s50% g{g}"))
            .run(|| gqs_gemv(&layer, &x, &mut y, &mut scratch));
        println!("{}", r.report());
    }
}
