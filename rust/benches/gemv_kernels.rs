//! Bench: GEMV kernels (Figure 6). Run via `cargo bench --bench gemv_kernels`.
//!
//! Criterion is not vendored in this offline image; the in-tree harness
//! (gqsa::bench::Bench) provides warmup + timed iterations. Ratios
//! between kernels are the reproduction target.

use gqsa::bench::Bench;
use gqsa::gqs::gemm::{gqs_gemm, MatmulScratch};
use gqsa::gqs::gemv::{gqs_gemv, gqs_gemv_ref};
use gqsa::gqs::gemv_dense::{dense_gemv, QuantDense, Semi24Kernel};
use gqsa::gqs::layer::GqsLayer;
use gqsa::model::config::demo_config;
use gqsa::model::transformer::random_fp;
use gqsa::model::{BlockScratch, KvCache, Scratch, Transformer};
use gqsa::sparse::group_prune::group_prune;
use gqsa::sparse::saliency::SaliencyMetric;
use gqsa::sparse::semi24::prune_24;
use gqsa::util::{Mat, XorShift};

/// Block-size sweep (T ∈ {1..32}): per-token GEMV vs one batched GEMM
/// walk on the W4S50% kernel setting, plus model-level prefill through
/// the same sweep; emits BENCH_batched_forward.json at the repo root.
fn block_sweep() {
    let ts = [1usize, 2, 4, 8, 16, 32];
    let (n, k) = (1024usize, 1024usize);
    let mut rng = XorShift::new(7);
    let w = Mat::randn(n, k, &mut rng);
    let mask = group_prune(&w, None, SaliencyMetric::Magnitude, 16, 0.5);
    let layer = GqsLayer::encode(&w, &mask, 4);

    println!("\n# block-size sweep — GQS W4 S50% G16 ({n}x{k} kernel / demo-config prefill)");
    let mut kernel_rows = Vec::new();
    for &t in &ts {
        let x = Mat::randn(t, k, &mut rng);
        let mut y = Mat::zeros(t, n);
        let mut mm = MatmulScratch::new();
        let batched =
            Bench::new(format!("matmul T={t}")).run(|| gqs_gemm(&layer, &x, &mut y, &mut mm));
        let mut yr = vec![0.0f32; n];
        let mut sc: Vec<f32> = Vec::new();
        let per_token = Bench::new(format!("{t} x gemv")).run(|| {
            for ti in 0..t {
                gqs_gemv(&layer, x.row(ti), &mut yr, &mut sc);
            }
        });
        let speedup = per_token.mean_us() / batched.mean_us();
        println!(
            "T={t:<3} per-token {:>9.1} us   batched {:>9.1} us   speedup {speedup:.2}x",
            per_token.mean_us(),
            batched.mean_us()
        );
        kernel_rows.push(format!(
            "    {{\"t\": {t}, \"per_token_us\": {:.2}, \"batched_us\": {:.2}, \"speedup\": {:.3}}}",
            per_token.mean_us(),
            batched.mean_us(),
            speedup
        ));
    }

    // model-level: per-token prefill vs block prefill on W4S50 weights
    let cfg = demo_config();
    let fp = random_fp(&cfg, 42);
    let model = Transformer::from_fp_gqs_oneshot(&fp, None, 4, 16, 0.5).unwrap();
    let prompt: Vec<u32> = (0..64u32).map(|i| (i * 37) % 256).collect();
    let mut model_rows = Vec::new();
    let mut scratch = Scratch::new(&cfg);
    let mut kv = KvCache::new(cfg.n_layers, cfg.n_heads, cfg.head_dim(), 128);
    let seq = Bench::new("prefill per-token").run(|| {
        kv.reset();
        model.prefill(&prompt, &mut kv, &mut scratch).unwrap();
    });
    let seq_tps = prompt.len() as f64 / (seq.mean_us() * 1e-6);
    println!("prefill per-token   {:>9.1} us  ({seq_tps:.0} tok/s)", seq.mean_us());
    for &chunk in &ts {
        let mut bs = BlockScratch::new(&cfg, chunk);
        let blk = Bench::new(format!("prefill chunk={chunk}")).run(|| {
            kv.reset();
            model.prefill_block(&prompt, &mut kv, &mut bs, chunk).unwrap();
        });
        let tps = prompt.len() as f64 / (blk.mean_us() * 1e-6);
        println!(
            "prefill chunk={chunk:<3} {:>9.1} us  ({tps:.0} tok/s, {:.2}x vs per-token)",
            blk.mean_us(),
            seq.mean_us() / blk.mean_us()
        );
        model_rows.push(format!(
            "    {{\"chunk\": {chunk}, \"us\": {:.2}, \"tok_per_s\": {tps:.1}, \"speedup\": {:.3}}}",
            blk.mean_us(),
            seq.mean_us() / blk.mean_us()
        ));
    }

    let json = format!(
        "{{\n  \"bench\": \"batched_forward\",\n  \"setting\": \"W4 S50% G16\",\n  \"kernel_shape\": [{n}, {k}],\n  \"kernel_sweep\": [\n{}\n  ],\n  \"prefill_per_token_us\": {:.2},\n  \"prefill_block_sweep\": [\n{}\n  ]\n}}\n",
        kernel_rows.join(",\n"),
        seq.mean_us(),
        model_rows.join(",\n")
    );
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_batched_forward.json");
    match std::fs::write(&out, json) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
}

fn main() {
    let (n, k) = (1024usize, 1024usize);
    let mut rng = XorShift::new(42);
    let w = Mat::randn(n, k, &mut rng);
    let x = rng.normal_vec(k);
    let mut y = vec![0.0f32; n];
    let mut scratch: Vec<f32> = Vec::new();

    println!("# GEMV kernel bench ({n}x{k}) — Figure 6 shape");

    let r_fp = Bench::new("fp32 dense").run(|| dense_gemv(&w, &x, &mut y));
    println!("{}", r_fp.report());

    for bits in [8u32, 4, 2] {
        let qd = QuantDense::encode(&w, bits, 16);
        let r = Bench::new(format!("w{bits} dense (fused dequant)")).run(|| {
            qd.gemv(&x, &mut y, &mut scratch)
        });
        println!("{}", r.report());
    }

    let w24 = prune_24(&w, None, SaliencyMetric::Magnitude);
    let k24 = Semi24Kernel::encode(&w24, 4, 16);
    let r_24 = Bench::new("w4 2:4 (metadata kernel)").run(|| k24.gemv(&x, &mut y));
    println!("{}", r_24.report());

    for s in [0.3f64, 0.5, 0.7] {
        let mask = group_prune(&w, None, SaliencyMetric::Magnitude, 16, s);
        let layer = GqsLayer::encode(&w, &mask, 4);
        let r = Bench::new(format!("GQS w4 s{:.0}% g16 (opt)", s * 100.0))
            .run(|| gqs_gemv(&layer, &x, &mut y, &mut scratch));
        println!("{}  [{:.2}x vs 2:4]", r.report(), r_24.mean_us() / r.mean_us());
        if s == 0.5 {
            let r_ref = Bench::new("GQS w4 s50% g16 (scalar ref)")
                .run(|| gqs_gemv_ref(&layer, &x, &mut y));
            println!(
                "{}  [opt speedup {:.2}x]",
                r_ref.report(),
                r_ref.mean_us() / r.mean_us()
            );
        }
    }

    for g in [8usize, 32, 128] {
        let mask = group_prune(&w, None, SaliencyMetric::Magnitude, g, 0.5);
        let layer = GqsLayer::encode(&w, &mask, 4);
        let r = Bench::new(format!("GQS w4 s50% g{g}"))
            .run(|| gqs_gemv(&layer, &x, &mut y, &mut scratch));
        println!("{}", r.report());
    }

    block_sweep();
}
