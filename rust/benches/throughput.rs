//! Bench: serving throughput through the continuous-batching
//! coordinator (Table 13 shape), plus a block-size sweep over the
//! batched forward path. `cargo bench --bench throughput`.
//!
//! The compression-variant comparison needs the trained artifacts
//! (`make artifacts`); the block sweep falls back to a random-weight
//! W4S50% model so it runs on a fresh checkout too.

use gqsa::bench::Workbench;
use gqsa::coordinator::{Backend, EngineConfig, EngineCore, Request};
use gqsa::model::config::demo_config;
use gqsa::model::transformer::random_fp;
use gqsa::model::Transformer;

/// Engine-level block sweep: the same request load through per-token
/// shaped configs (chunk=1, batch=1) up to fully batched ones.
fn engine_block_sweep() {
    let cfg = demo_config();
    let fp = random_fp(&cfg, 42);
    println!("\n# engine block sweep — synthetic W4S50%G16, 8 requests x 32 tokens, input 24");
    let mut base = 0.0f64;
    for (label, chunk, batch) in [
        ("per-token  (chunk 1, batch 1)", 1usize, 1usize),
        ("chunked    (chunk 16, batch 1)", 16, 1),
        ("batched    (chunk 1, batch 8)", 1, 8),
        ("block+batch (chunk 16, batch 8)", 16, 8),
    ] {
        let model = Transformer::from_fp_gqs_oneshot(&fp, None, 4, 16, 0.5).unwrap();
        let mut engine = EngineCore::new(
            Backend::Native(model),
            &cfg,
            EngineConfig { max_batch: batch, prefill_chunk: chunk, kv_capacity: 128, ..Default::default() },
        )
        .unwrap();
        for i in 0..8u64 {
            let prompt: Vec<u32> = (0..24u32).map(|j| (i as u32 * 31 + j * 7) % 256).collect();
            engine.submit(Request::new(i, prompt, 32));
        }
        let t0 = std::time::Instant::now();
        let out = engine.run_to_completion().unwrap();
        let secs = t0.elapsed().as_secs_f64();
        let tokens: usize = out.iter().map(|r| r.n_prompt + r.tokens.len()).sum();
        let tps = tokens as f64 / secs;
        if base == 0.0 {
            base = tps;
        }
        println!("{label:<32} {tps:>8.1} tok/s   ({:.2}x vs per-token)", tps / base);
    }
}

fn main() {
    engine_block_sweep();

    let art = Workbench::default_dir();
    if !art.join("models/tiny-llama.fp.bin").exists() {
        eprintln!("artifacts missing — run `make artifacts` first; skipping variant table");
        return;
    }
    let mut wb = Workbench::new(art);
    println!("\n# serving throughput: 8 requests x 64 tokens, batch 4, input 15");
    let mut base = 0.0f64;
    for (label, spec) in [
        ("fp32", "fp"),
        ("w8", "w8"),
        ("w8 s50", "gqsa:w8s50g16"),
        ("w4", "w4"),
        ("w4 s50", "gqsa:w4s50g16"),
    ] {
        let model = match wb.variant("tiny-llama", spec) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("{label}: {e:#} (skipped)");
                continue;
            }
        };
        let cfg = model.cfg.clone();
        let mut engine = EngineCore::new(
            Backend::Native(model),
            &cfg,
            EngineConfig { max_batch: 4, prefill_chunk: 15, kv_capacity: 128, ..Default::default() },
        )
        .unwrap();
        let corpus = wb.corpus("wiki_syn").unwrap().to_vec();
        for i in 0..8u64 {
            let start = (i as usize * 53) % 2000;
            let prompt: Vec<u32> = corpus[start..start + 15].iter().map(|&b| u32::from(b)).collect();
            engine.submit(Request::new(i, prompt, 64));
        }
        let t0 = std::time::Instant::now();
        let out = engine.run_to_completion().unwrap();
        let secs = t0.elapsed().as_secs_f64();
        let tokens: usize = out.iter().map(|r| r.tokens.len()).sum();
        let tps = tokens as f64 / secs;
        if base == 0.0 {
            base = tps;
        }
        println!("{label:<10} {tps:>8.1} tok/s   ({:.2}x vs fp32)", tps / base);
    }
    println!("# paper shape (Table 13): W4S50 > W4 > W8S50 > W8 > FP");
}
