//! Bench: end-to-end decode latency across compression settings
//! (Tables 4/10/16, Figure 7 shape). `cargo bench --bench e2e_latency`.

use gqsa::bench::Workbench;

fn main() {
    let art = Workbench::default_dir();
    if !art.join("models/tiny-llama.fp.bin").exists() {
        eprintln!("artifacts missing — run `make artifacts` first; skipping");
        return;
    }
    let mut wb = Workbench::new(art);
    println!("# e2e decode latency, input len 15 — tiny-llama");
    for (label, spec) in [
        ("fp32", "fp"),
        ("w8", "w8"),
        ("w4", "w4"),
        ("w2", "w2"),
        ("w4 2:4", "w4-24"),
        ("gqsa w4s30", "gqsa:w4s30g16"),
        ("gqsa w4s50", "gqsa:w4s50g16"),
        ("gqsa w8s50", "gqsa:w8s50g16"),
    ] {
        let model = match wb.variant("tiny-llama", spec) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("{label}: {e:#} (skipped)");
                continue;
            }
        };
        print!("{label:<14}");
        for out_len in [128usize, 512] {
            let ms = wb.decode_latency_ms(&model, 15, out_len).unwrap();
            print!("  len{out_len}: {ms:>8.1} ms");
        }
        println!("  weights: {:>7.2} MB", model.weight_bytes() as f64 / 1048576.0);
    }
}
