"""Synthetic corpus generator properties."""

import numpy as np

from compile import data


def test_corpus_length_and_charset():
    c = data.generate_corpus(seed=1, n_bytes=5000)
    assert len(c) == 5000
    allowed = set(b"abcdefghijklmnopqrstuvwxyz. ")
    assert set(c) <= allowed


def test_corpus_deterministic():
    assert data.generate_corpus(seed=5, n_bytes=2000) == data.generate_corpus(seed=5, n_bytes=2000)


def test_corpus_seeds_differ():
    assert data.generate_corpus(seed=1, n_bytes=2000) != data.generate_corpus(seed=2, n_bytes=2000)


def test_zipf_skew():
    """Word frequencies should be heavy-tailed: top decile >> uniform share."""
    c = data.generate_corpus(seed=3, n_bytes=50000)
    words = c.split()
    uniq, counts = np.unique(words, return_counts=True)
    counts = np.sort(counts)[::-1]
    top10 = counts[: max(1, len(counts) // 10)].sum() / counts.sum()
    assert top10 > 0.35, top10


def test_bigram_structure_present():
    """Markov successor table should make bigrams non-uniform."""
    c = data.generate_corpus(seed=4, n_bytes=80000)
    words = c.replace(b". ", b" ").split()
    from collections import Counter, defaultdict
    succ = defaultdict(Counter)
    for a, b in zip(words, words[1:]):
        succ[a][b] += 1
    # among frequent words, the most common successor should dominate
    freq = Counter(words).most_common(20)
    ratios = []
    for w, _ in freq:
        s = succ[w]
        if sum(s.values()) >= 20:
            ratios.append(s.most_common(1)[0][1] / sum(s.values()))
    assert ratios and np.mean(ratios) > 0.08
