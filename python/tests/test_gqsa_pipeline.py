"""Pipeline-level tests: calibration, saliency, BQPO/E2E-OQP improve error,
BSR export round-trips, container format."""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from compile import common, gqsa, model
from compile.common import ModelConfig
from compile.kernels import ref


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(family="t", vocab=64, d_model=64, n_layers=2, n_heads=2, d_ff=96, max_seq=64)
    p = model.init_params(cfg, 7)
    rng = np.random.default_rng(0)
    corpus = rng.integers(0, 64, size=20000).astype(np.uint8)
    seqs = gqsa.calib_batches(corpus, n_seq=4, ctx=48)
    pj = {k: jnp.asarray(v) for k, v in p.items()}
    hess, blk_in, fp_logits = gqsa.calibrate(cfg, pj, seqs)
    return cfg, p, corpus, seqs, hess, blk_in, fp_logits


class TestCalibration:
    def test_hessian_psd_and_shape(self, setup):
        cfg, p, *_ , hess, _, _ = (*setup[:4], setup[4], setup[5], setup[6])
        for n in model.linear_names(cfg):
            h = hess[n]
            assert h.shape[0] == h.shape[1] == p[n].shape[1]
            ev = np.linalg.eigvalsh(h)
            assert ev.min() > -1e-6 * max(1.0, ev.max())

    def test_block_inputs_shape(self, setup):
        cfg, _, _, seqs, _, blk_in, _ = setup
        for i in range(cfg.n_layers):
            assert blk_in[i].shape == (seqs.shape[0], seqs.shape[1], cfg.d_model)

    def test_hinv_diag_positive(self, setup):
        _, _, _, _, hess, _, _ = setup
        for h in hess.values():
            assert np.all(gqsa.hinv_diag(h) > 0)


class TestSaliencyMasks:
    def test_saliency_prefers_large_weights(self):
        w = np.ones((4, 64), dtype=np.float32) * 0.01
        w[:, :16] = 5.0  # one huge group
        hd = np.ones(64)
        sc = gqsa.saliency(w, hd, 16)
        assert np.all(sc[:, 0] > sc[:, 1:].max(axis=1))

    def test_saliency_uses_hessian(self):
        w = np.ones((2, 32), dtype=np.float32)
        hd = np.ones(32)
        hd[:16] = 0.1  # low H^-1 diag => high saliency
        sc = gqsa.saliency(w, hd, 16)
        assert sc[0, 0] > sc[0, 1]

    def test_build_masks_sparsity(self, setup):
        cfg, p, _, _, hess, _, _ = setup
        masks = gqsa.build_masks(cfg, p, hess, 0.5, 16)
        for n, m in masks.items():
            assert abs(1.0 - m.mean() - 0.5) < 0.13


class TestOptimization:
    def test_bqpo_reduces_block_error(self, setup):
        cfg, p, _, seqs, hess, blk_in, _ = setup
        masks = gqsa.build_masks(cfg, p, hess, 0.5, 16)
        log = []
        gqsa.bqpo(cfg, p, masks, 4, 16, blk_in, steps=12, lr=3e-4, log=log)
        assert len(log) == cfg.n_layers
        improved = sum(1 for r in log if r["loss_last"] < r["loss_first"])
        assert improved >= cfg.n_layers - 1, log

    def test_e2e_oqp_reduces_logit_error(self, setup):
        cfg, p, _, seqs, hess, blk_in, fp_logits = setup
        masks = gqsa.build_masks(cfg, p, hess, 0.5, 16)
        frozen, sz = gqsa.freeze_quantize(cfg, p, masks, 4, 16)
        log = []
        gqsa.e2e_oqp(cfg, p, frozen, sz, 16, seqs, fp_logits, steps=12, lr=3e-4, batch=2, log=log)
        assert log[0]["e2e_loss_last"] < log[0]["e2e_loss_first"], log

    def test_freeze_quantize_codes_integral(self, setup):
        cfg, p, _, _, hess, _, _ = setup
        masks = gqsa.build_masks(cfg, p, hess, 0.3, 16)
        frozen, sz = gqsa.freeze_quantize(cfg, p, masks, 4, 16)
        for n, (q, m) in frozen.items():
            qn = np.asarray(q)
            np.testing.assert_allclose(qn, np.round(qn), atol=1e-5)
            assert qn.min() >= 0 and qn.max() <= 15


class TestPacking:
    @pytest.mark.parametrize("bits", [2, 4, 8])
    def test_pack_roundtrip(self, bits):
        rng = np.random.default_rng(0)
        codes = rng.integers(0, 2**bits, size=64).astype(np.uint8)
        packed = gqsa.pack_nibbles(codes, bits)
        # unpack
        if bits == 8:
            un = packed
        elif bits == 4:
            un = np.empty(packed.size * 2, np.uint8)
            un[0::2], un[1::2] = packed & 0xF, packed >> 4
        else:
            un = np.empty(packed.size * 4, np.uint8)
            for j in range(4):
                un[j::4] = (packed >> (2 * j)) & 0x3
        np.testing.assert_array_equal(un[: len(codes)], codes)

    def test_pack_density(self):
        codes = np.zeros(128, np.uint8)
        assert gqsa.pack_nibbles(codes, 4).size == 64
        assert gqsa.pack_nibbles(codes, 2).size == 32


class TestExport:
    def test_export_roundtrip_dense_equivalence(self, setup, tmp_path):
        """BSR export -> reload -> dense reconstruction == wmap_frozen_q dense."""
        cfg, p, _, _, hess, _, _ = setup
        masks = gqsa.build_masks(cfg, p, hess, 0.5, 16)
        frozen, sz = gqsa.freeze_quantize(cfg, p, masks, 4, 16)
        out = tmp_path / "m.gqsa"
        gqsa.export_gqsa(out, cfg, p, frozen, sz, masks, 4, 16, 0.5)
        tensors, meta = common.load_tensors(out)
        assert meta["bits"] == 4 and meta["group"] == 16
        n = model.linear_names(cfg)[0]
        rp = tensors[n + ".row_ptr"]
        cols = tensors[n + ".cols"]
        packed = tensors[n + ".qvals"]
        scales = tensors[n + ".scales"]
        zeros = tensors[n + ".zeros"]
        # reconstruct dense
        codes = np.empty(packed.size * 2, np.float32)
        codes[0::2], codes[1::2] = (packed & 0xF), (packed >> 4)
        codes = codes[: rp[-1] * 16].reshape(-1, 16)
        nrows, k = p[n].shape
        dense = np.zeros((nrows, k), np.float32)
        for r in range(nrows):
            for j in range(rp[r], rp[r + 1]):
                c = cols[j]
                dense[r, c * 16 : (c + 1) * 16] = (codes[j] - zeros[j]) * scales[j]
        # oracle dense from frozen q + sz
        wm = model.wmap_frozen_q(cfg, {k2: jnp.asarray(v) for k2, v in p.items()},
                                 frozen, sz, 16)
        np.testing.assert_allclose(dense, np.asarray(wm(n)), atol=1e-4)

    def test_row_ptr_monotone_and_counts(self, setup, tmp_path):
        cfg, p, _, _, hess, _, _ = setup
        masks = gqsa.build_masks(cfg, p, hess, 0.4, 16)
        frozen, sz = gqsa.freeze_quantize(cfg, p, masks, 4, 16)
        out = tmp_path / "m2.gqsa"
        gqsa.export_gqsa(out, cfg, p, frozen, sz, masks, 4, 16, 0.4)
        tensors, _ = common.load_tensors(out)
        for n in model.linear_names(cfg):
            rp = tensors[n + ".row_ptr"]
            assert np.all(np.diff(rp) >= 0)
            assert rp[-1] == len(tensors[n + ".cols"]) == len(tensors[n + ".scales"])


class TestContainer:
    def test_save_load_all_dtypes(self, tmp_path):
        rng = np.random.default_rng(0)
        tensors = {
            "f": rng.normal(size=(3, 4)).astype(np.float32),
            "i": rng.integers(-5, 5, size=(7,)).astype(np.int32),
            "b": rng.integers(0, 255, size=(9,)).astype(np.uint8),
            "s": rng.integers(-3, 3, size=(2, 2, 2)).astype(np.int8),
        }
        common.save_tensors(tmp_path / "t.bin", tensors, meta={"x": 1, "y": [1, 2]})
        back, meta = common.load_tensors(tmp_path / "t.bin")
        assert meta == {"x": 1, "y": [1, 2]}
        for k in tensors:
            np.testing.assert_array_equal(back[k], tensors[k])

    def test_scalar_and_empty(self, tmp_path):
        common.save_tensors(tmp_path / "e.bin", {"z": np.zeros(0, np.float32)})
        back, _ = common.load_tensors(tmp_path / "e.bin")
        assert back["z"].size == 0
