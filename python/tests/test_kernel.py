"""L1 correctness: the Pallas GQS GEMV kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes / group sizes / sparsities / bit-widths; every
case asserts allclose against dense-reconstruction semantics.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gqs_gemv, ref


def make_gqs(seed, n, k, g, bits, sparsity):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(n, k)).astype(np.float32)
    scores = rng.random((n, k // g))
    mask = ref.group_mask_from_scores(scores, sparsity)
    return ref.encode(w, mask, bits, g), w, mask, rng


# ---------------------------------------------------------------------------
# Deterministic unit cases
# ---------------------------------------------------------------------------

class TestQuantParams:
    def test_scale_zero_paper_convention(self):
        g = jnp.asarray([[0.0, 1.5, 3.0, -1.5]])
        s, z = ref.quant_params(g, 4)
        assert np.isclose(float(s[0]), 4.5 / 15.0)
        assert float(z[0]) == -np.floor(-1.5 / float(s[0]))

    def test_constant_group_does_not_nan(self):
        g = jnp.full((1, 16), 2.5)
        out = ref.quant_dequant(g, 4)
        assert np.all(np.isfinite(np.asarray(out)))

    def test_codes_in_range(self):
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
        for bits in (2, 3, 4, 8):
            s, z = ref.quant_params(g, bits)
            q = np.asarray(ref.quantize(g, s, z, bits))
            assert q.min() >= 0 and q.max() <= 2**bits - 1

    def test_quant_error_bounded_by_scale(self):
        # interior points err <= s/2; range edges can clip by up to one
        # full step (z = -floor(min/s) biases the top of the range).
        rng = np.random.default_rng(1)
        g = jnp.asarray(rng.normal(size=(4, 16)).astype(np.float32))
        s, _ = ref.quant_params(g, 4)
        err = np.abs(np.asarray(ref.quant_dequant(g, 4) - g))
        assert np.all(err <= np.asarray(s)[..., None] * 1.0001 + 1e-6)

    def test_more_bits_less_error(self):
        rng = np.random.default_rng(2)
        g = jnp.asarray(rng.normal(size=(16, 16)).astype(np.float32))
        errs = [float(jnp.mean((ref.quant_dequant(g, b) - g) ** 2)) for b in (2, 4, 8)]
        assert errs[0] > errs[1] > errs[2]


class TestGroupPruning:
    def test_mask_keeps_exact_fraction(self):
        scores = np.random.default_rng(0).random((32, 16))
        for s in (0.25, 0.5, 0.75):
            m = ref.group_mask_from_scores(scores, s)
            assert np.all(m.sum(1) == round(16 * (1 - s)))

    def test_mask_keeps_top_scores(self):
        scores = np.arange(8.0)[None].repeat(4, 0)
        m = ref.group_mask_from_scores(scores, 0.5)
        assert np.array_equal(m[0], np.array([0, 0, 0, 0, 1, 1, 1, 1], bool))

    def test_at_least_one_group_survives(self):
        scores = np.random.default_rng(0).random((4, 8))
        m = ref.group_mask_from_scores(scores, 0.99)
        assert np.all(m.sum(1) >= 1)


class TestEncodeDecode:
    def test_decode_zeroes_pruned_groups(self):
        gqs, w, mask, _ = make_gqs(0, 32, 64, 16, 4, 0.5)
        dense = np.asarray(ref.decode_dense(gqs)).reshape(32, 4, 16)
        assert np.all(dense[~mask] == 0.0)

    def test_decode_close_on_kept_groups(self):
        gqs, w, mask, _ = make_gqs(1, 32, 64, 16, 8, 0.25)
        dense = np.asarray(ref.decode_dense(gqs)).reshape(32, 4, 16)
        wg = w.reshape(32, 4, 16)
        err = np.abs(dense[mask] - wg[mask])
        assert err.max() < 0.05  # 8-bit on unit-normal data

    def test_gemv_matches_gather_formulation(self):
        gqs, _, _, rng = make_gqs(2, 48, 96, 16, 4, 0.4)
        x = jnp.asarray(rng.normal(size=(96,)).astype(np.float32))
        a = ref.gqs_gemv_ref(gqs, x)
        b = ref.gqs_gemv_gather_ref(gqs, x)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


# ---------------------------------------------------------------------------
# Kernel vs oracle — fixed grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [16, 64, 100])
@pytest.mark.parametrize("k", [64, 256])
@pytest.mark.parametrize("sparsity", [0.0, 0.5])
def test_kernel_matches_oracle_grid(n, k, sparsity):
    gqs, _, _, rng = make_gqs(3, n, k, 16, 4, sparsity)
    x = jnp.asarray(rng.normal(size=(k,)).astype(np.float32))
    y_ref = np.asarray(ref.gqs_gemv_ref(gqs, x))
    y_ker = np.asarray(gqs_gemv.gqs_gemv(gqs, x))
    np.testing.assert_allclose(y_ker, y_ref, atol=2e-4, rtol=1e-4)


@pytest.mark.parametrize("block_n", [8, 32, 128])
def test_kernel_block_size_invariance(block_n):
    gqs, _, _, rng = make_gqs(4, 96, 128, 16, 4, 0.5)
    x = jnp.asarray(rng.normal(size=(128,)).astype(np.float32))
    y_ref = np.asarray(ref.gqs_gemv_ref(gqs, x))
    y_ker = np.asarray(gqs_gemv.gqs_gemv(gqs, x, block_n=block_n))
    np.testing.assert_allclose(y_ker, y_ref, atol=2e-4, rtol=1e-4)


def test_kernel_batched_matmul():
    gqs, _, _, rng = make_gqs(5, 64, 64, 16, 4, 0.5)
    xb = jnp.asarray(rng.normal(size=(5, 64)).astype(np.float32))
    y_ref = np.asarray(ref.gqs_matmul_ref(gqs, xb))
    y_ker = np.asarray(gqs_gemv.gqs_matmul(gqs, xb))
    np.testing.assert_allclose(y_ker, y_ref, atol=2e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# Hypothesis sweeps (shapes, dtypes of x, sparsity, group, bits)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 80),
    ng=st.integers(1, 8),
    g=st.sampled_from([4, 8, 16, 32]),
    bits=st.sampled_from([2, 4, 8]),
    sparsity=st.floats(0.0, 0.9),
    seed=st.integers(0, 2**16),
)
def test_kernel_matches_oracle_hypothesis(n, ng, g, bits, sparsity, seed):
    k = ng * g
    gqs, _, _, rng = make_gqs(seed, n, k, g, bits, sparsity)
    x = jnp.asarray(rng.normal(size=(k,)).astype(np.float32))
    y_ref = np.asarray(ref.gqs_gemv_ref(gqs, x))
    y_ker = np.asarray(gqs_gemv.gqs_gemv(gqs, x))
    np.testing.assert_allclose(y_ker, y_ref, atol=5e-4, rtol=1e-3)


@settings(max_examples=10, deadline=None)
@given(
    dtype=st.sampled_from([np.float32, np.float16]),
    scale=st.floats(1e-3, 1e3),
    seed=st.integers(0, 2**16),
)
def test_kernel_x_dtype_and_scale(dtype, scale, seed):
    gqs, _, _, rng = make_gqs(seed, 32, 64, 16, 4, 0.5)
    x = (rng.normal(size=(64,)) * scale).astype(dtype)
    y_ref = np.asarray(ref.gqs_gemv_ref(gqs, jnp.asarray(x, dtype=jnp.float32)))
    y_ker = np.asarray(gqs_gemv.gqs_gemv(gqs, jnp.asarray(x, dtype=jnp.float32)))
    np.testing.assert_allclose(y_ker, y_ref, atol=5e-3 * scale, rtol=1e-3)


@settings(max_examples=10, deadline=None)
@given(sparsity=st.floats(0.0, 0.95), seed=st.integers(0, 2**16))
def test_padding_slots_never_contribute(sparsity, seed):
    """Rows with fewer groups than MG must ignore their padding slots."""
    rng = np.random.default_rng(seed)
    n, k, g = 16, 64, 16
    w = rng.normal(size=(n, k)).astype(np.float32)
    # ragged mask: row i keeps i%4+1 groups -> heavy padding
    mask = np.zeros((n, k // g), bool)
    for i in range(n):
        keep = rng.choice(k // g, size=i % (k // g) + 1, replace=False)
        mask[i, keep] = True
    gqs = ref.encode(w, mask, 4, g)
    x = jnp.asarray(rng.normal(size=(k,)).astype(np.float32))
    y_ref = np.asarray(ref.gqs_gemv_ref(gqs, x))
    y_ker = np.asarray(gqs_gemv.gqs_gemv(gqs, x))
    np.testing.assert_allclose(y_ker, y_ref, atol=5e-4, rtol=1e-3)


def test_vmem_estimate_fits_tpu_budget():
    """Paper-scale tile (K=4096, G=16, 50% sparsity) must fit 16 MiB VMEM."""
    est = gqs_gemv.vmem_estimate_bytes(n=4096, k=4096, mg=128, g=16, bn=gqs_gemv.DEFAULT_BN)
    assert est["total_tpu"] < 16 * 1024 * 1024
