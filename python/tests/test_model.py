"""L2 correctness: model forward variants, decode consistency, GQS routing."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.common import FAMILIES, ModelConfig
from compile.kernels import ref


def tiny_cfg(**kw) -> ModelConfig:
    base = dict(family="t", vocab=64, d_model=64, n_layers=2, n_heads=2, d_ff=96, max_seq=64)
    base.update(kw)
    return ModelConfig(**base)


def jparams(cfg, seed=0):
    return {k: jnp.asarray(v) for k, v in model.init_params(cfg, seed).items()}


CFGS = [
    tiny_cfg(),
    tiny_cfg(pos="learned", act="gelu", norm="layernorm"),
    tiny_cfg(qkv_bias=True, n_heads=4),
]


@pytest.mark.parametrize("cfg", CFGS, ids=["llama-like", "gpt-like", "qwen-like"])
class TestForward:
    def test_shapes(self, cfg):
        p = jparams(cfg)
        toks = jnp.arange(10, dtype=jnp.int32)
        logits = model.forward(cfg, p, toks)
        assert logits.shape == (10, cfg.vocab)
        assert np.all(np.isfinite(np.asarray(logits)))

    def test_causality(self, cfg):
        """Changing a future token must not affect earlier logits."""
        p = jparams(cfg)
        a = jnp.asarray([1, 2, 3, 4, 5, 6], dtype=jnp.int32)
        b = a.at[5].set(60)
        la = np.asarray(model.forward(cfg, p, a))
        lb = np.asarray(model.forward(cfg, p, b))
        np.testing.assert_allclose(la[:5], lb[:5], atol=1e-5)
        assert not np.allclose(la[5], lb[5])

    def test_decode_matches_prefill(self, cfg):
        p = jparams(cfg)
        toks = jnp.asarray([3, 17, 42, 9, 25, 1], dtype=jnp.int32)
        full = np.asarray(model.forward(cfg, p, toks))
        kv = jnp.zeros((cfg.n_layers, 2, cfg.n_heads, 32, cfg.head_dim))
        outs = []
        for i, t in enumerate(toks):
            lg, kv = model.decode_step(cfg, p, t, jnp.asarray(i, dtype=jnp.int32), kv)
            outs.append(np.asarray(lg))
        np.testing.assert_allclose(np.stack(outs), full, atol=5e-4, rtol=1e-3)

    def test_batch_matches_single(self, cfg):
        p = jparams(cfg)
        toks = jnp.asarray([[1, 2, 3, 4], [9, 8, 7, 6]], dtype=jnp.int32)
        lb = np.asarray(model.forward_batch(cfg, p, toks))
        for i in range(2):
            np.testing.assert_allclose(
                lb[i], np.asarray(model.forward(cfg, p, toks[i])), atol=1e-5
            )


class TestCapture:
    def test_capture_matches_forward(self):
        cfg = tiny_cfg()
        p = jparams(cfg)
        toks = jnp.arange(8, dtype=jnp.int32)
        l1 = np.asarray(model.forward(cfg, p, toks))
        l2, caps = model.forward_capture(cfg, p, toks)
        np.testing.assert_allclose(l1, np.asarray(l2), atol=1e-5)
        for n in model.linear_names(cfg):
            assert n in caps and caps[n].shape[0] == 8

    def test_block_apply_consistent_with_capture(self):
        cfg = tiny_cfg()
        p = jparams(cfg)
        toks = jnp.arange(8, dtype=jnp.int32)
        _, caps = model.forward_capture(cfg, p, toks)
        x0 = caps["blk0.__in__"][None]
        y = model.block_apply(cfg, p, lambda n: p[n], 0, x0)
        np.testing.assert_allclose(
            np.asarray(y[0]), np.asarray(caps["blk1.__in__"]), atol=1e-5
        )


class TestGQSRouting:
    def _gqs_layers(self, cfg, p, sparsity=0.5, bits=4, group=16):
        layers = {}
        rng = np.random.default_rng(0)
        for n in model.linear_names(cfg):
            w = np.asarray(p[n])
            scores = rng.random((w.shape[0], w.shape[1] // group))
            mask = ref.group_mask_from_scores(scores, sparsity)
            layers[n] = ref.encode(w, mask, bits, group)
        return layers

    def test_forward_gqs_matches_dense_oracle(self):
        cfg = tiny_cfg()
        p = jparams(cfg)
        layers = self._gqs_layers(cfg, p)
        toks = jnp.arange(6, dtype=jnp.int32)
        wm = model.wmap_gqs_dense(p, layers)
        l_dense = np.asarray(model.forward(cfg, p, toks, wm))
        l_kernel = np.asarray(model.forward_gqs(cfg, p, toks, layers))
        np.testing.assert_allclose(l_kernel, l_dense, atol=5e-3, rtol=1e-3)

    def test_decode_gqs_matches_dense_oracle(self):
        cfg = tiny_cfg()
        p = jparams(cfg)
        layers = self._gqs_layers(cfg, p)
        wm = model.wmap_gqs_dense(p, layers)
        toks = jnp.asarray([3, 1, 4, 1, 5], dtype=jnp.int32)
        kv1 = jnp.zeros((cfg.n_layers, 2, cfg.n_heads, 16, cfg.head_dim))
        kv2 = kv1
        for i, t in enumerate(toks):
            pos = jnp.asarray(i, dtype=jnp.int32)
            l1, kv1 = model.decode_step(cfg, p, t, pos, kv1, wm)
            l2, kv2 = model.decode_step_gqs(cfg, p, t, pos, kv2, layers)
            np.testing.assert_allclose(np.asarray(l2), np.asarray(l1), atol=5e-3, rtol=1e-3)

    def test_qdq_ste_map_zeroes_pruned(self):
        cfg = tiny_cfg()
        p = jparams(cfg)
        group = 16
        n0 = model.linear_names(cfg)[0]
        mask = np.zeros((p[n0].shape[0], p[n0].shape[1] // group), bool)
        mask[:, 0] = True
        wm = model.wmap_qdq_ste(cfg, p, {n0: mask}, 4, group)
        w = np.asarray(wm(n0))
        assert np.all(w[:, group:] == 0.0)
        assert np.any(w[:, :group] != 0.0)


class TestLossEval:
    def test_lm_loss_decreases_with_training_signal(self):
        # loss on repeated token should be lower after biasing embeddings
        cfg = tiny_cfg()
        p = jparams(cfg)
        toks = jnp.asarray([[7] * 16], dtype=jnp.int32)
        l = float(model.lm_loss(cfg, p, toks))
        assert np.isfinite(l) and l > 0

    def test_perplexity_uniform_near_vocab(self):
        cfg = tiny_cfg()
        p = jparams(cfg, seed=3)
        data = np.random.default_rng(0).integers(0, cfg.vocab, size=4096).astype(np.uint8)
        ppl = model.perplexity(cfg, p, data, ctx=64, max_windows=4)
        assert 0.3 * cfg.vocab < ppl < 3 * cfg.vocab
