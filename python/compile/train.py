"""Build-time training of the tiny model families on the synthetic corpus.

AdamW, a few hundred steps — enough to pull ppl well below the uniform
baseline (256) so compression-induced degradation is measurable, which
is all the paper's tables need (they report *relative* degradation
between compression settings).
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import common, data, model
from .common import ART, FAMILIES, ModelConfig, StageTimer


def adamw_init(params):
    z = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": z, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": jnp.zeros(())}


def adamw_update(params, grads, opt, lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, wd=0.01):
    t = opt["t"] + 1.0
    new_m, new_v, new_p = {}, {}, {}
    for k in params:
        m = b1 * opt["m"][k] + (1 - b1) * grads[k]
        v = b2 * opt["v"][k] + (1 - b2) * grads[k] ** 2
        mhat = m / (1 - b1**t)
        vhat = v / (1 - b2**t)
        upd = mhat / (jnp.sqrt(vhat) + eps)
        if params[k].ndim >= 2:
            upd = upd + wd * params[k]
        new_p[k] = params[k] - lr * upd
        new_m[k], new_v[k] = m, v
    return new_p, {"m": new_m, "v": new_v, "t": t}


def batches(corpus: np.ndarray, batch: int, ctx: int, seed: int):
    rng = np.random.default_rng(seed)
    n = len(corpus) - ctx - 1
    while True:
        idx = rng.integers(0, n, size=batch)
        yield np.stack([corpus[i : i + ctx + 1] for i in idx]).astype(np.int32)


def train_family(cfg: ModelConfig, corpus: np.ndarray, steps: int = 400,
                 batch: int = 8, ctx: int = 192, lr: float = 3e-4,
                 log_every: int = 50) -> tuple[dict, list]:
    params = {k: jnp.asarray(v) for k, v in model.init_params(cfg, seed=hash(cfg.family) % 2**31).items()}
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, toks):
        loss, grads = jax.value_and_grad(lambda p: model.lm_loss(cfg, p, toks))(params)
        params, opt = adamw_update(params, grads, opt, lr=lr)
        return params, opt, loss

    gen = batches(corpus, batch, ctx, seed=99)
    log = []
    t0 = time.time()
    for i in range(steps):
        toks = jnp.asarray(next(gen))
        params, opt, loss = step(params, opt, toks)
        if i % log_every == 0 or i == steps - 1:
            l = float(loss)
            log.append({"step": i, "loss": round(l, 4), "elapsed_s": round(time.time() - t0, 1)})
            print(f"[{cfg.family}] step {i:4d} loss {l:.4f}", flush=True)
    return {k: np.asarray(v) for k, v in params.items()}, log


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--families", nargs="*", default=list(FAMILIES))
    args = ap.parse_args()

    corpus = np.frombuffer((ART / "corpus" / "train.bin").read_bytes(), dtype=np.uint8)
    wiki = np.frombuffer((ART / "corpus" / "wiki_syn.bin").read_bytes(), dtype=np.uint8)
    timer = StageTimer()
    for fam in args.families:
        cfg = FAMILIES[fam]
        with timer.stage(f"train.{fam}"):
            params, log = train_family(cfg, corpus, steps=args.steps)
        ppl = model.perplexity(cfg, {k: jnp.asarray(v) for k, v in params.items()}, wiki, max_windows=16)
        print(f"[{fam}] wiki_syn ppl {ppl:.3f}")
        common.save_tensors(
            ART / "models" / f"{fam}.fp.bin", params,
            meta={"config": cfg.to_json(), "train_log": log, "wiki_syl_ppl": ppl},
        )
    timer.dump(ART / "logs" / "train_times.json")


if __name__ == "__main__":
    main()
