"""Paste artifacts/results/*.txt into EXPERIMENTS.md §Measured.

Run after `gqsa bench-table all`; idempotent (replaces the MEASURED
block each time).
"""
from pathlib import Path

ROOT = Path(__file__).resolve().parents[2]
RESULTS = ROOT / "artifacts" / "results"
EXP = ROOT / "EXPERIMENTS.md"

def main():
    parts = []
    if RESULTS.exists():
        for p in sorted(RESULTS.glob("*.txt")):
            parts.append(f"#### {p.stem}\n```\n{p.read_text().rstrip()}\n```\n")
    blob = "<!-- MEASURED -->\n\n" + "\n".join(parts) if parts else "<!-- MEASURED -->\n\n(no results yet)"
    text = EXP.read_text()
    head, _, tail = text.partition("<!-- MEASURED -->")
    # keep everything after the next "---" section break following the marker
    rest = tail.split("\n---\n", 1)
    suffix = ("\n---\n" + rest[1]) if len(rest) > 1 else ""
    EXP.write_text(head + blob + suffix)
    print(f"pasted {len(parts)} result tables into EXPERIMENTS.md")

if __name__ == "__main__":
    main()
