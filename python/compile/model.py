"""Layer-2: tiny transformer families in functional JAX.

Three families stand in for the paper's LLaMA / OPT / Qwen2.5 model
zoos (DESIGN.md §Hardware-Adaptation):

  * tiny-llama : RMSNorm + RoPE + SwiGLU, tied embeddings
  * tiny-gpt   : LayerNorm + learned positions + GELU (OPT-analogue)
  * tiny-qwen  : llama-like with qkv bias, different widths

The forward is written against a *weight map*: any 2D linear weight can
be substituted (quant-dequant STE during BQPO/E2E-OQP, dense during
training, GQS-dequantized during validation) without touching the graph.
A separate builder (`forward_gqs`) routes every linear through the
Layer-1 Pallas kernel for the AOT inference artifacts.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .common import ModelConfig
from .kernels import gqs_gemv, ref

# Names of the 2D linear weights GQSA compresses, per block.
LINEAR_NAMES = ("attn.wq", "attn.wk", "attn.wv", "attn.wo", "mlp.w1", "mlp.w2", "mlp.w3")


def linear_names(cfg: ModelConfig) -> list[str]:
    """Fully-qualified names of every GQS-compressible weight."""
    per_blk = list(LINEAR_NAMES)
    if cfg.act != "swiglu":
        per_blk.remove("mlp.w2")
    return [f"blk{i}.{n}" for i in range(cfg.n_layers) for n in per_blk]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, seed: int = 0) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab

    def w(shape, fan_in):
        return (rng.normal(size=shape) * (fan_in**-0.5)).astype(np.float32)

    p: dict[str, np.ndarray] = {"tok_emb": (rng.normal(size=(v, d)) * 0.02).astype(np.float32)}
    if cfg.pos == "learned":
        p["pos_emb"] = (rng.normal(size=(cfg.max_seq, d)) * 0.02).astype(np.float32)
    for i in range(cfg.n_layers):
        pre = f"blk{i}."
        for nm in ("attn.wq", "attn.wk", "attn.wv", "attn.wo"):
            p[pre + nm] = w((d, d), d)
        if cfg.qkv_bias:
            for nm in ("attn.bq", "attn.bk", "attn.bv"):
                p[pre + nm] = np.zeros(d, dtype=np.float32)
        if cfg.act == "swiglu":
            p[pre + "mlp.w1"] = w((ff, d), d)
            p[pre + "mlp.w2"] = w((ff, d), d)
            p[pre + "mlp.w3"] = w((d, ff), ff)
        else:
            p[pre + "mlp.w1"] = w((ff, d), d)
            p[pre + "mlp.w3"] = w((d, ff), ff)
        p[pre + "norm1"] = np.ones(d, dtype=np.float32)
        p[pre + "norm2"] = np.ones(d, dtype=np.float32)
        if cfg.norm == "layernorm":
            p[pre + "norm1.bias"] = np.zeros(d, dtype=np.float32)
            p[pre + "norm2.bias"] = np.zeros(d, dtype=np.float32)
    p["final_norm"] = np.ones(d, dtype=np.float32)
    if cfg.norm == "layernorm":
        p["final_norm.bias"] = np.zeros(d, dtype=np.float32)
    if not cfg.tie_embeddings:
        p["head"] = w((v, d), d)
    return p


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def _norm(cfg: ModelConfig, p, x, name: str):
    if cfg.norm == "rmsnorm":
        ms = jnp.mean(x * x, axis=-1, keepdims=True)
        return x * jax.lax.rsqrt(ms + 1e-6) * p[name]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-6) * p[name] + p[name + ".bias"]


def _rope(x: jnp.ndarray, positions: jnp.ndarray) -> jnp.ndarray:
    """x: (..., T, H, Dh); rotate pairs with theta base 10000."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 10000.0 ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., T, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # (...,T,1,half)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


WMap = Callable[[str], jnp.ndarray]


def _attn(cfg: ModelConfig, p, wm: WMap, pre: str, x, positions, kv=None, mask=None):
    """Self-attention. x: (T, D). kv: optional (2, H, Tmax, Dh) cache with
    write position = positions[0]; returns (out, new_kv)."""
    t, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    q = x @ wm(pre + "attn.wq").T
    k = x @ wm(pre + "attn.wk").T
    v = x @ wm(pre + "attn.wv").T
    if cfg.qkv_bias:
        q, k, v = q + p[pre + "attn.bq"], k + p[pre + "attn.bk"], v + p[pre + "attn.bv"]
    q = q.reshape(t, h, dh)
    k = k.reshape(t, h, dh)
    v = v.reshape(t, h, dh)
    if cfg.pos == "rope":
        q, k = _rope(q, positions), _rope(k, positions)

    if kv is None:
        att = jnp.einsum("qhd,khd->hqk", q, k) / jnp.sqrt(dh)
        causal = jnp.tril(jnp.ones((t, t), dtype=bool))
        att = jnp.where(causal[None], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        out = jnp.einsum("hqk,khd->qhd", att, v)
        new_kv = None
    else:
        # Single-token decode: t == 1, write k/v at positions[0].
        pos = positions[0]
        kcache = kv[0].at[:, pos].set(k[0])
        vcache = kv[1].at[:, pos].set(v[0])
        tmax = kcache.shape[1]
        att = jnp.einsum("hd,htd->ht", q[0], kcache) / jnp.sqrt(dh)
        valid = jnp.arange(tmax) <= pos
        att = jnp.where(valid[None], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        out = jnp.einsum("ht,htd->hd", att, vcache)[None]
        new_kv = jnp.stack([kcache, vcache])
    out = out.reshape(t, d) @ wm(pre + "attn.wo").T
    return out, new_kv


def _mlp(cfg: ModelConfig, wm: WMap, pre: str, x):
    if cfg.act == "swiglu":
        g = x @ wm(pre + "mlp.w1").T
        u = x @ wm(pre + "mlp.w2").T
        return (jax.nn.silu(g) * u) @ wm(pre + "mlp.w3").T
    hdn = jax.nn.gelu(x @ wm(pre + "mlp.w1").T)
    return hdn @ wm(pre + "mlp.w3").T


# ---------------------------------------------------------------------------
# Full forwards
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, p: dict, tokens: jnp.ndarray, wmap: WMap | None = None) -> jnp.ndarray:
    """Dense forward. tokens: (T,) int32 -> logits (T, V).

    ``wmap(name)`` substitutes any 2D linear weight (STE quant-dequant,
    pruning masks, ...); defaults to the raw parameter.
    """
    wm: WMap = wmap if wmap is not None else (lambda n: p[n])
    t = tokens.shape[0]
    x = p["tok_emb"][tokens]
    positions = jnp.arange(t)
    if cfg.pos == "learned":
        x = x + p["pos_emb"][:t]
    for i in range(cfg.n_layers):
        pre = f"blk{i}."
        a, _ = _attn(cfg, p, wm, pre, _norm(cfg, p, x, pre + "norm1"), positions)
        x = x + a
        x = x + _mlp(cfg, wm, pre, _norm(cfg, p, x, pre + "norm2"))
    x = _norm(cfg, p, x, "final_norm")
    head = p["tok_emb"] if cfg.tie_embeddings else p["head"]
    return x @ head.T


def forward_batch(cfg: ModelConfig, p: dict, tokens: jnp.ndarray, wmap: WMap | None = None) -> jnp.ndarray:
    """tokens: (B, T) -> (B, T, V)."""
    return jax.vmap(lambda tk: forward(cfg, p, tk, wmap))(tokens)


def decode_step(cfg: ModelConfig, p: dict, token: jnp.ndarray, pos: jnp.ndarray,
                kv: jnp.ndarray, wmap: WMap | None = None):
    """Single-token KV-cached decode.

    token: () int32; pos: () int32; kv: (L, 2, H, Tmax, Dh).
    Returns (logits (V,), new_kv). This is the function AOT-lowered for
    the Rust PJRT serving backend.
    """
    wm: WMap = wmap if wmap is not None else (lambda n: p[n])
    x = p["tok_emb"][token][None]            # (1, D)
    if cfg.pos == "learned":
        x = x + p["pos_emb"][pos][None]
    positions = pos[None]
    new_kv = []
    for i in range(cfg.n_layers):
        pre = f"blk{i}."
        a, nkv = _attn(cfg, p, wm, pre, _norm(cfg, p, x, pre + "norm1"), positions, kv=kv[i])
        new_kv.append(nkv)
        x = x + a
        x = x + _mlp(cfg, wm, pre, _norm(cfg, p, x, pre + "norm2"))
    x = _norm(cfg, p, x, "final_norm")
    head = p["tok_emb"] if cfg.tie_embeddings else p["head"]
    return (x @ head.T)[0], jnp.stack(new_kv)


def block_apply(cfg: ModelConfig, p: dict, wm: WMap, i: int, x: jnp.ndarray) -> jnp.ndarray:
    """Apply transformer block i to batched hidden states x: (B, T, D).

    Used by BQPO to optimize one block against the FP block's outputs.
    """
    pre = f"blk{i}."
    t = x.shape[1]
    positions = jnp.arange(t)

    def one(xb):
        a, _ = _attn(cfg, p, wm, pre, _norm(cfg, p, xb, pre + "norm1"), positions)
        xb = xb + a
        return xb + _mlp(cfg, wm, pre, _norm(cfg, p, xb, pre + "norm2"))

    return jax.vmap(one)(x)


def forward_capture(cfg: ModelConfig, p: dict, tokens: jnp.ndarray):
    """Dense forward that also returns the input matrix of every linear.

    Returns (logits, {linear_name: (T, K) inputs}, {f"blk{i}.__in__": (T, D)}).
    Feeds Hessian calibration (H = X^T X) and BQPO block targets.
    """
    caps: dict[str, jnp.ndarray] = {}
    t = tokens.shape[0]
    x = p["tok_emb"][tokens]
    positions = jnp.arange(t)
    if cfg.pos == "learned":
        x = x + p["pos_emb"][:t]
    h, dh = cfg.n_heads, cfg.head_dim
    for i in range(cfg.n_layers):
        pre = f"blk{i}."
        caps[pre + "__in__"] = x
        xn = _norm(cfg, p, x, pre + "norm1")
        caps[pre + "attn.wq"] = xn
        caps[pre + "attn.wk"] = xn
        caps[pre + "attn.wv"] = xn
        q = xn @ p[pre + "attn.wq"].T
        k = xn @ p[pre + "attn.wk"].T
        v = xn @ p[pre + "attn.wv"].T
        if cfg.qkv_bias:
            q, k, v = q + p[pre + "attn.bq"], k + p[pre + "attn.bk"], v + p[pre + "attn.bv"]
        q = q.reshape(t, h, dh)
        k = k.reshape(t, h, dh)
        v = v.reshape(t, h, dh)
        if cfg.pos == "rope":
            q, k = _rope(q, positions), _rope(k, positions)
        att = jnp.einsum("qhd,khd->hqk", q, k) / jnp.sqrt(dh)
        causal = jnp.tril(jnp.ones((t, t), dtype=bool))
        att = jax.nn.softmax(jnp.where(causal[None], att, -1e30), axis=-1)
        a = jnp.einsum("hqk,khd->qhd", att, v).reshape(t, cfg.d_model)
        caps[pre + "attn.wo"] = a
        x = x + a @ p[pre + "attn.wo"].T
        xn = _norm(cfg, p, x, pre + "norm2")
        caps[pre + "mlp.w1"] = xn
        if cfg.act == "swiglu":
            caps[pre + "mlp.w2"] = xn
            g = xn @ p[pre + "mlp.w1"].T
            u = xn @ p[pre + "mlp.w2"].T
            hdn = jax.nn.silu(g) * u
        else:
            hdn = jax.nn.gelu(xn @ p[pre + "mlp.w1"].T)
        caps[pre + "mlp.w3"] = hdn
        x = x + hdn @ p[pre + "mlp.w3"].T
    x = _norm(cfg, p, x, "final_norm")
    head = p["tok_emb"] if cfg.tie_embeddings else p["head"]
    return x @ head.T, caps


# ---------------------------------------------------------------------------
# Weight-map builders
# ---------------------------------------------------------------------------

def wmap_qdq_ste(cfg: ModelConfig, p: dict, masks: dict[str, np.ndarray],
                 bits: int, group: int) -> WMap:
    """Quantization-aware STE weight map for BQPO.

    Surviving groups are fake-quantized with a straight-through gradient;
    pruned groups are hard-zeroed. ``masks[name]`` is the (N, K//G)
    keep-mask.
    """
    def wm(name: str) -> jnp.ndarray:
        w = p[name]
        if name not in masks:
            return w
        n, k = w.shape
        wg = w.reshape(n, k // group, group)
        qdq = ref.quant_dequant(wg, bits)
        ste = wg + jax.lax.stop_gradient(qdq - wg)
        m = jnp.asarray(masks[name], dtype=jnp.float32)[..., None]
        return (ste * m).reshape(n, k)
    return wm


def wmap_frozen_q(cfg: ModelConfig, p: dict, frozen: dict[str, tuple],
                  sz: dict, group: int) -> WMap:
    """E2E-OQP weight map: integer codes frozen, (scale, zero) trainable.

    ``frozen[name] = (q (N,NG,G) float-ints, mask (N,NG))``;
    ``sz[name] = {"s": (N,NG), "z": (N,NG)}`` live in the optimized pytree.
    """
    def wm(name: str) -> jnp.ndarray:
        if name not in frozen:
            return p[name]
        q, mask = frozen[name]
        s, z = sz[name]["s"], sz[name]["z"]
        deq = (q - z[..., None]) * s[..., None]
        deq = deq * jnp.asarray(mask, dtype=jnp.float32)[..., None]
        n, ng, g = q.shape
        return deq.reshape(n, ng * g)
    return wm


def wmap_gqs_dense(p: dict, layers: dict[str, ref.GQSWeights]) -> WMap:
    """Validation map: GQS layers dense-reconstructed through the oracle."""
    def wm(name: str) -> jnp.ndarray:
        if name in layers:
            return ref.decode_dense(layers[name])
        return p[name]
    return wm


def forward_gqs(cfg: ModelConfig, p: dict, tokens: jnp.ndarray,
                layers: dict[str, ref.GQSWeights], block_n: int = 64) -> jnp.ndarray:
    """Forward routing every GQS linear through the Layer-1 Pallas kernel.

    Used by the AOT path so the exported HLO contains the kernel's
    (interpret-mode) lowering; numerics must match `forward` with
    `wmap_gqs_dense` (tested in python/tests).
    """
    def wm_mat(name: str):
        if name in layers:
            gqs = layers[name]
            return lambda x: gqs_gemv.gqs_matmul(gqs, x, block_n=block_n)
        return lambda x: x @ p[name].T

    # Inline forward with kernel-routed linears.
    t = tokens.shape[0]
    x = p["tok_emb"][tokens]
    positions = jnp.arange(t)
    if cfg.pos == "learned":
        x = x + p["pos_emb"][:t]
    h, dh = cfg.n_heads, cfg.head_dim
    for i in range(cfg.n_layers):
        pre = f"blk{i}."
        xn = _norm(cfg, p, x, pre + "norm1")
        q = wm_mat(pre + "attn.wq")(xn)
        k = wm_mat(pre + "attn.wk")(xn)
        v = wm_mat(pre + "attn.wv")(xn)
        if cfg.qkv_bias:
            q, k, v = q + p[pre + "attn.bq"], k + p[pre + "attn.bk"], v + p[pre + "attn.bv"]
        q = q.reshape(t, h, dh)
        k = k.reshape(t, h, dh)
        v = v.reshape(t, h, dh)
        if cfg.pos == "rope":
            q, k = _rope(q, positions), _rope(k, positions)
        att = jnp.einsum("qhd,khd->hqk", q, k) / jnp.sqrt(dh)
        causal = jnp.tril(jnp.ones((t, t), dtype=bool))
        att = jax.nn.softmax(jnp.where(causal[None], att, -1e30), axis=-1)
        a = jnp.einsum("hqk,khd->qhd", att, v).reshape(t, cfg.d_model)
        x = x + wm_mat(pre + "attn.wo")(a)
        xn = _norm(cfg, p, x, pre + "norm2")
        if cfg.act == "swiglu":
            g = wm_mat(pre + "mlp.w1")(xn)
            u = wm_mat(pre + "mlp.w2")(xn)
            x = x + wm_mat(pre + "mlp.w3")(jax.nn.silu(g) * u)
        else:
            x = x + wm_mat(pre + "mlp.w3")(jax.nn.gelu(wm_mat(pre + "mlp.w1")(xn)))
    x = _norm(cfg, p, x, "final_norm")
    head = p["tok_emb"] if cfg.tie_embeddings else p["head"]
    return x @ head.T


def decode_step_gqs(cfg: ModelConfig, p: dict, token: jnp.ndarray, pos: jnp.ndarray,
                    kv: jnp.ndarray, layers: dict[str, ref.GQSWeights],
                    block_n: int = 64):
    """KV-cached decode with every GQS linear routed through the Layer-1
    Pallas GEMV kernel — the AOT hot path the Rust PJRT backend executes.

    Semantics must match `decode_step` with `wmap_gqs_dense` (tested).
    """
    def mv(name: str, x_vec: jnp.ndarray) -> jnp.ndarray:
        if name in layers:
            return gqs_gemv.gqs_gemv(layers[name], x_vec, block_n=block_n)
        return p[name] @ x_vec

    h, dh = cfg.n_heads, cfg.head_dim
    x = p["tok_emb"][token]                     # (D,)
    if cfg.pos == "learned":
        x = x + p["pos_emb"][pos]
    new_kv = []
    for i in range(cfg.n_layers):
        pre = f"blk{i}."
        xn = _norm(cfg, p, x[None], pre + "norm1")[0]
        q = mv(pre + "attn.wq", xn)
        k = mv(pre + "attn.wk", xn)
        v = mv(pre + "attn.wv", xn)
        if cfg.qkv_bias:
            q, k, v = q + p[pre + "attn.bq"], k + p[pre + "attn.bk"], v + p[pre + "attn.bv"]
        q = q.reshape(h, dh)
        k = k.reshape(h, dh)
        v = v.reshape(h, dh)
        if cfg.pos == "rope":
            q = _rope(q[None], pos[None])[0]
            k = _rope(k[None], pos[None])[0]
        kcache = kv[i, 0].at[:, pos].set(k)
        vcache = kv[i, 1].at[:, pos].set(v)
        tmax = kcache.shape[1]
        att = jnp.einsum("hd,htd->ht", q, kcache) / jnp.sqrt(dh)
        valid = jnp.arange(tmax) <= pos
        att = jax.nn.softmax(jnp.where(valid[None], att, -1e30), axis=-1)
        a = jnp.einsum("ht,htd->hd", att, vcache).reshape(cfg.d_model)
        x = x + mv(pre + "attn.wo", a)
        new_kv.append(jnp.stack([kcache, vcache]))
        xn = _norm(cfg, p, x[None], pre + "norm2")[0]
        if cfg.act == "swiglu":
            g = mv(pre + "mlp.w1", xn)
            u = mv(pre + "mlp.w2", xn)
            x = x + mv(pre + "mlp.w3", jax.nn.silu(g) * u)
        else:
            x = x + mv(pre + "mlp.w3", jax.nn.gelu(mv(pre + "mlp.w1", xn)))
    x = _norm(cfg, p, x[None], "final_norm")[0]
    head = p["tok_emb"] if cfg.tie_embeddings else p["head"]
    return head @ x, jnp.stack(new_kv)


# ---------------------------------------------------------------------------
# Loss / eval helpers
# ---------------------------------------------------------------------------

def lm_loss(cfg: ModelConfig, p: dict, tokens: jnp.ndarray, wmap: WMap | None = None) -> jnp.ndarray:
    """Next-token cross-entropy over a (B, T) batch."""
    logits = forward_batch(cfg, p, tokens[:, :-1], wmap)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def perplexity(cfg: ModelConfig, p: dict, data: np.ndarray, ctx: int = 256,
               wmap: WMap | None = None, max_windows: int = 64) -> float:
    """Sliding-window ppl over a byte array (matches the rust evaluator)."""
    n_win = min(max_windows, (len(data) - 1) // ctx)
    tot, cnt = 0.0, 0
    fwd = jax.jit(lambda tk: forward(cfg, p, tk, wmap))
    for i in range(n_win):
        chunk = jnp.asarray(data[i * ctx : i * ctx + ctx + 1].astype(np.int32))
        logits = fwd(chunk[:-1])
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, chunk[1:, None], axis=-1)[:, 0]
        tot += float(jnp.sum(nll))
        cnt += ctx
    return float(np.exp(tot / max(cnt, 1)))
