"""Synthetic corpus generation (WikiText2/C4 stand-ins).

The paper evaluates language-modeling perplexity on WikiText2 and C4.
Neither is available in this offline image, so we synthesize two corpora
from the same generator family with different seeds/parameters:

  * ``wiki_syn`` — Zipf-distributed word vocabulary, order-1 word-level
    Markov chain with topical state (bursty, wiki-like repetition).
  * ``c4_syn``   — same generator, different seed, flatter Zipf exponent
    and more topics (web-crawl-ish heterogeneity).

Words are rendered as lowercase ASCII strings separated by spaces with
sentence punctuation, so the byte-level models see realistic structure
(whitespace, frequent short tokens, punctuation).  Every compression
method is evaluated on the *same* held-out split, so the rankings the
paper's tables report are preserved (see DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import numpy as np

from . import common


def _make_words(rng: np.random.Generator, n_words: int) -> list[bytes]:
    """Random pronounceable-ish words, 2-9 chars."""
    cons = b"bcdfghjklmnpqrstvwz"
    vows = b"aeiou"
    words = []
    for _ in range(n_words):
        n_syll = int(rng.integers(1, 4))
        w = bytearray()
        for _ in range(n_syll):
            w.append(cons[int(rng.integers(len(cons)))])
            w.append(vows[int(rng.integers(len(vows)))])
            if rng.random() < 0.3:
                w.append(cons[int(rng.integers(len(cons)))])
        words.append(bytes(w))
    return words


def generate_corpus(
    seed: int,
    n_bytes: int,
    n_words: int = 2000,
    n_topics: int = 8,
    zipf_a: float = 1.3,
    topic_stick: float = 0.98,
) -> bytes:
    """Topical Zipf-Markov byte corpus of ~n_bytes bytes."""
    rng = np.random.default_rng(seed)
    words = _make_words(rng, n_words)

    # Global Zipf ranks; per-topic reweighting concentrates on a subset.
    ranks = np.arange(1, n_words + 1, dtype=np.float64)
    base = ranks ** (-zipf_a)
    topic_w = np.empty((n_topics, n_words))
    for t in range(n_topics):
        boost = np.zeros(n_words)
        idx = rng.choice(n_words, size=n_words // n_topics, replace=False)
        boost[idx] = 6.0
        w = base * (1.0 + boost)
        topic_w[t] = w / w.sum()

    # Order-1 Markov: next word from mixture of topic unigram and a sparse
    # per-word successor table (bigram structure the models can learn).
    n_succ = 6
    succ = rng.integers(0, n_words, size=(n_words, n_succ))

    out = bytearray()
    topic = int(rng.integers(n_topics))
    word = int(rng.choice(n_words, p=topic_w[topic]))
    sent_len = 0
    while len(out) < n_bytes:
        out += words[word]
        sent_len += 1
        if rng.random() < 0.12 and sent_len > 3:
            out += b". "
            sent_len = 0
        else:
            out += b" "
        if rng.random() > topic_stick:
            topic = int(rng.integers(n_topics))
        if rng.random() < 0.55:
            word = int(succ[word, int(rng.integers(n_succ))])
        else:
            word = int(rng.choice(n_words, p=topic_w[topic]))
    return bytes(out[:n_bytes])


def build_all(out_dir=None, train_bytes: int = 2_000_000, eval_bytes: int = 131_072) -> dict:
    """Write train + two eval corpora; returns paths.

    Both eval sets share the train generator's *word vocabulary* (same
    seed => same `_make_words` draw), like WikiText2/C4 sharing English:
      * wiki_syn — the held-out continuation of the train stream (same
        distribution, unseen text);
      * c4_syn   — same words, flatter Zipf + more topics (domain shift).
    Early versions used disjoint word sets, which made eval ppl *rise*
    as models sharpened — pure OOD, useless for ranking compression.
    """
    out_dir = common.ART / "corpus" if out_dir is None else out_dir
    out_dir.mkdir(parents=True, exist_ok=True)
    full = generate_corpus(seed=1234, n_bytes=train_bytes + eval_bytes, zipf_a=1.3, n_topics=8)
    paths = {}
    for name, data in {
        "train": full[:train_bytes],
        "wiki_syn": full[train_bytes:],
        "c4_syn": generate_corpus(seed=1234, n_bytes=eval_bytes, zipf_a=1.15, n_topics=16),
    }.items():
        p = out_dir / f"{name}.bin"
        p.write_bytes(data)
        paths[name] = str(p)
    return paths


if __name__ == "__main__":
    print(build_all())
