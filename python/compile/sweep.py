"""Build-time compression sweep: every GQSA variant the paper's tables need.

Per family: W4 S{20,30,40,50} G16 (Tables 1/14/15, 2/3, 4, 13, 16).
tiny-llama extras:
  * S{60,70,80} G16            — Fig. 8 left (sparsity ablation)
  * S50 G{8,32,64,128}         — Fig. 8 right (group-size ablation)
  * S50 bqpo-only / one-shot   — Table 6 (stage ablation)
  * W8 S50 G16                 — Table 13 (W8S50 row)

Headline settings get more optimization steps than ablation points; the
step counts are recorded in each artifact's meta.
"""

from __future__ import annotations

import sys
import time

from . import gqsa
from .common import FAMILIES

HEADLINE = dict(bqpo_steps=60, e2e_steps=60)
STANDARD = dict(bqpo_steps=30, e2e_steps=30)
ABLATION = dict(bqpo_steps=15, e2e_steps=15)


def run():
    t_start = time.time()
    jobs: list[tuple] = []
    for fam in FAMILIES:
        if fam.startswith("_"):
            continue
        for s in (0.2, 0.3, 0.4, 0.5):
            prof = HEADLINE if s == 0.5 else STANDARD
            jobs.append((fam, dict(sparsity=s, group=16, bits=4, **prof)))
    fam = "tiny-llama"
    for s in (0.6, 0.7, 0.8):
        jobs.append((fam, dict(sparsity=s, group=16, bits=4, **ABLATION)))
    for g in (8, 32, 64, 128):
        jobs.append((fam, dict(sparsity=0.5, group=g, bits=4, **ABLATION)))
    jobs.append((fam, dict(sparsity=0.5, group=16, bits=4, bqpo_steps=60, e2e_steps=0,
                           tag="w4s50g16-bqpo")))
    jobs.append((fam, dict(sparsity=0.5, group=16, bits=4, bqpo_steps=0, e2e_steps=0,
                           tag="w4s50g16-oneshot")))
    jobs.append((fam, dict(sparsity=0.5, group=16, bits=8, **STANDARD)))

    caches: dict[str, dict] = {}
    for i, (fam, kw) in enumerate(jobs):
        t0 = time.time()
        gqsa.compress(fam, **kw, _cache=caches.setdefault(fam, {}))
        print(f"  job {i+1}/{len(jobs)} done in {time.time()-t0:.0f}s "
              f"(total {time.time()-t_start:.0f}s)", flush=True)


if __name__ == "__main__":
    run()
    print("sweep complete", file=sys.stderr)
