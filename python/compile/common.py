"""Shared build-time utilities: the GQTB tensor container, model configs.

The GQTB binary container is the python<->rust interchange for weights,
compressed (.gqsa) models, corpora and logs. Layout (little-endian):

    magic   b"GQTB"
    u32     version (1)
    u32     ntensors
    per tensor:
        u16  name_len, name bytes (utf-8)
        u8   dtype  (0=f32, 1=i32, 2=u8, 3=i8, 4=u16, 5=i64)
        u8   ndim
        u64  dims[ndim]
        u64  nbytes
        raw  bytes

A tensor named ``__meta__`` (dtype u8) holds a UTF-8 JSON blob with
free-form metadata (model config, compression settings, ...).
"""

from __future__ import annotations

import dataclasses
import json
import struct
import time
from pathlib import Path

import numpy as np

MAGIC = b"GQTB"
VERSION = 1

_DTYPES = {
    0: np.float32,
    1: np.int32,
    2: np.uint8,
    3: np.int8,
    4: np.uint16,
    5: np.int64,
}
_DTYPE_IDS = {np.dtype(v): k for k, v in _DTYPES.items()}


def save_tensors(path: str | Path, tensors: dict[str, np.ndarray], meta: dict | None = None) -> None:
    """Write a GQTB container. ``meta`` is stored as the __meta__ tensor."""
    items = dict(tensors)
    if meta is not None:
        items["__meta__"] = np.frombuffer(json.dumps(meta).encode("utf-8"), dtype=np.uint8).copy()
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(items)))
        for name, arr in items.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype == np.float64:
                arr = arr.astype(np.float32)
            if arr.dtype == np.bool_:
                arr = arr.astype(np.uint8)
            dt = _DTYPE_IDS.get(arr.dtype)
            if dt is None:
                raise ValueError(f"unsupported dtype {arr.dtype} for tensor {name}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", dt, arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}Q", *arr.shape))
            raw = arr.tobytes()
            f.write(struct.pack("<Q", len(raw)))
            f.write(raw)


def load_tensors(path: str | Path) -> tuple[dict[str, np.ndarray], dict]:
    """Read a GQTB container; returns (tensors, meta)."""
    tensors: dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, f"bad magic in {path}"
        version, n = struct.unpack("<II", f.read(8))
        assert version == VERSION
        for _ in range(n):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode("utf-8")
            dt, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}Q", f.read(8 * ndim)) if ndim else ()
            (nbytes,) = struct.unpack("<Q", f.read(8))
            raw = f.read(nbytes)
            tensors[name] = np.frombuffer(raw, dtype=_DTYPES[dt]).reshape(dims).copy()
    meta = {}
    if "__meta__" in tensors:
        meta = json.loads(tensors.pop("__meta__").tobytes().decode("utf-8"))
    return tensors, meta


@dataclasses.dataclass
class ModelConfig:
    """Tiny transformer family config (see DESIGN.md §Hardware-Adaptation)."""

    family: str
    vocab: int = 256
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 512
    max_seq: int = 1088
    pos: str = "rope"        # "rope" | "learned"
    act: str = "swiglu"      # "swiglu" | "gelu"
    norm: str = "rmsnorm"    # "rmsnorm" | "layernorm"
    qkv_bias: bool = False
    tie_embeddings: bool = True

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: dict) -> "ModelConfig":
        return ModelConfig(**d)


FAMILIES: dict[str, ModelConfig] = {
    # LLaMA-analogue: RoPE + SwiGLU + RMSNorm (Tables 1-13, Fig 6-8).
    "tiny-llama": ModelConfig("tiny-llama", d_model=256, n_layers=4, n_heads=4, d_ff=512),
    # OPT-analogue: learned positions + GELU + LayerNorm (Table 15).
    "tiny-gpt": ModelConfig(
        "tiny-gpt", d_model=192, n_layers=4, n_heads=4, d_ff=768,
        pos="learned", act="gelu", norm="layernorm",
    ),
    # Qwen2.5-analogue: llama-like with qkv bias, different widths (Table 14).
    "tiny-qwen": ModelConfig(
        "tiny-qwen", d_model=320, n_layers=3, n_heads=5, d_ff=640, qkv_bias=True,
    ),
}


class StageTimer:
    """Record wall-time + peak RSS per pipeline stage (Table 5 inputs)."""

    def __init__(self) -> None:
        self.records: list[dict] = []

    def stage(self, name: str):
        return _Stage(self, name)

    def dump(self, path: str | Path) -> None:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.records, indent=2))


class _Stage:
    def __init__(self, timer: StageTimer, name: str) -> None:
        self.timer, self.name = timer, name

    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *exc):
        import resource

        peak_kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        self.timer.records.append(
            {"stage": self.name, "seconds": round(time.time() - self.t0, 3),
             "peak_rss_mb": round(peak_kb / 1024.0, 1)}
        )
        return False


ART = Path(__file__).resolve().parents[2] / "artifacts"
