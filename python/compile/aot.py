"""AOT lowering: jax -> HLO *text* artifacts for the Rust PJRT runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

For each requested artifact we emit three files under artifacts/hlo/:

  <name>.hlo.txt        — the HLO module
  <name>.inputs.bin     — GQTB container with the *weight* inputs, named
                          in000..inNNN in exact HLO parameter order
  <name>.manifest.json  — input/output schema: how many leading weight
                          params, then the runtime params (tokens / token,
                          pos, kv) with shapes+dtypes, and output arity.

The Rust side (`rust/src/runtime/`) loads all three, creates the weight
literals once at startup, and appends the runtime literals per call —
Python never runs on the request path.
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import common, model
from .common import ART, FAMILIES, ModelConfig
from .kernels import ref


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(a) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype)


def _emit(name: str, lowered, weight_arrays: list[np.ndarray], runtime_params: list[dict],
          outputs: list[dict]) -> None:
    out_dir = ART / "hlo"
    out_dir.mkdir(parents=True, exist_ok=True)
    text = to_hlo_text(lowered)
    (out_dir / f"{name}.hlo.txt").write_text(text)
    tensors = {f"in{i:03d}": np.asarray(a) for i, a in enumerate(weight_arrays)}
    common.save_tensors(out_dir / f"{name}.inputs.bin", tensors)
    manifest = {
        "name": name,
        "n_weight_inputs": len(weight_arrays),
        "runtime_params": runtime_params,
        "outputs": outputs,
        "hlo_chars": len(text),
    }
    (out_dir / f"{name}.manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"[aot] {name}: {len(text)} chars, {len(weight_arrays)} weight inputs")


def _load_fp(family: str):
    tensors, meta = common.load_tensors(ART / "models" / f"{family}.fp.bin")
    cfg = ModelConfig.from_json(meta["config"])
    return cfg, tensors


def _load_gqs_layers(family: str, tag: str):
    """Rebuild padded-kernel GQSWeights from a .gqsa BSR container."""
    tensors, meta = common.load_tensors(ART / "models" / f"{family}.{tag}.gqsa")
    cfg = ModelConfig.from_json(meta["config"])
    bits, group = meta["bits"], meta["group"]
    layers: dict[str, ref.GQSWeights] = {}
    dense = {}
    for n in list(tensors):
        if n.endswith(".row_ptr"):
            base = n[: -len(".row_ptr")]
            rp = tensors[base + ".row_ptr"]
            cols = tensors[base + ".cols"]
            qpacked = tensors[base + ".qvals"]
            scales = tensors[base + ".scales"]
            zeros = tensors[base + ".zeros"]
            nrows = len(rp) - 1
            # unpack nibbles
            if bits == 4:
                lo = (qpacked & 0xF).astype(np.float32)
                hi = (qpacked >> 4).astype(np.float32)
                codes = np.empty(qpacked.size * 2, np.float32)
                codes[0::2], codes[1::2] = lo, hi
            elif bits == 8:
                codes = qpacked.astype(np.float32)
            else:
                raise ValueError(bits)
            codes = codes[: rp[-1] * group].reshape(rp[-1], group)
            counts = np.diff(rp)
            mg = max(int(counts.max()), 1)
            ng_total = None
            qv = np.zeros((nrows, mg, group), np.float32)
            sc = np.zeros((nrows, mg), np.float32)
            zp = np.zeros((nrows, mg), np.float32)
            gi = np.zeros((nrows, mg), np.int32)
            mask_cols = []
            for r in range(nrows):
                a, b = rp[r], rp[r + 1]
                c = b - a
                qv[r, :c] = codes[a:b]
                sc[r, :c] = scales[a:b]
                zp[r, :c] = zeros[a:b].astype(np.float32)
                gi[r, :c] = cols[a:b]
                mask_cols.append(cols[a:b])
            # Infer K from the model config by matching layer name at use time;
            # here we derive NG from max col + 1 is unsafe — store via meta.
            layers[base] = (qv, sc, zp, gi)
        elif not any(n.endswith(s) for s in (".cols", ".qvals", ".scales", ".zeros")):
            dense[n] = tensors[n]
    return cfg, dense, layers, bits, group


def _gqs_from_padded(padded, k_in: int, bits: int, group: int) -> ref.GQSWeights:
    qv, sc, zp, gi = padded
    n, mg, g = qv.shape
    ng = k_in // group
    mask = np.zeros((n, ng), dtype=bool)  # reconstructed; only used for accounting
    return ref.GQSWeights(jnp.asarray(qv), jnp.asarray(sc), jnp.asarray(zp),
                          jnp.asarray(gi), jnp.asarray(mask), bits, group, k_in)


def emit_prefill_dense(family: str, seq_len: int) -> None:
    cfg, tensors = _load_fp(family)
    names = sorted(tensors)
    arrays = [tensors[n] for n in names]

    def fn(weights, tokens):
        p = dict(zip(names, weights))
        return (model.forward(cfg, p, tokens),)

    specs = ([_spec(a) for a in arrays], jax.ShapeDtypeStruct((seq_len,), jnp.int32))
    lowered = jax.jit(fn).lower(specs[0], specs[1])
    _emit(f"{family}.prefill{seq_len}", lowered, arrays,
          [{"name": "tokens", "shape": [seq_len], "dtype": "i32"}],
          [{"name": "logits", "shape": [seq_len, cfg.vocab], "dtype": "f32"}])


def emit_decode_dense(family: str, t_max: int) -> None:
    cfg, tensors = _load_fp(family)
    names = sorted(tensors)
    arrays = [tensors[n] for n in names]
    kv_shape = (cfg.n_layers, 2, cfg.n_heads, t_max, cfg.head_dim)

    def fn(weights, token, pos, kv):
        p = dict(zip(names, weights))
        logits, new_kv = model.decode_step(cfg, p, token, pos, kv)
        return (logits, new_kv)

    lowered = jax.jit(fn).lower(
        [_spec(a) for a in arrays],
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct(kv_shape, jnp.float32),
    )
    _emit(f"{family}.decode", lowered, arrays,
          [{"name": "token", "shape": [], "dtype": "i32"},
           {"name": "pos", "shape": [], "dtype": "i32"},
           {"name": "kv", "shape": list(kv_shape), "dtype": "f32"}],
          [{"name": "logits", "shape": [cfg.vocab], "dtype": "f32"},
           {"name": "kv", "shape": list(kv_shape), "dtype": "f32"}])


def emit_decode_gqs(family: str, tag: str, t_max: int) -> None:
    """Decode step with the Pallas GQS GEMV kernel on every linear."""
    cfg, dense, padded_layers, bits, group = _load_gqs_layers(family, tag)
    # K for each layer from the dense model config
    kmap = {}
    for n in model.linear_names(cfg):
        if "mlp.w3" in n:
            kmap[n] = cfg.d_ff
        else:
            kmap[n] = cfg.d_model
    dnames = sorted(dense)
    lnames = sorted(padded_layers)
    arrays: list[np.ndarray] = [dense[n] for n in dnames]
    for n in lnames:
        arrays.extend(np.asarray(a) for a in padded_layers[n])
    kv_shape = (cfg.n_layers, 2, cfg.n_heads, t_max, cfg.head_dim)

    def fn(weights, token, pos, kv):
        p = dict(zip(dnames, weights[: len(dnames)]))
        layers = {}
        off = len(dnames)
        for i, n in enumerate(lnames):
            qv, sc, zp, gi = weights[off + 4 * i : off + 4 * i + 4]
            k_in = kmap[n]
            layers[n] = ref.GQSWeights(qv, sc, zp, gi, jnp.zeros((1, 1), bool), bits, group, k_in)
        logits, new_kv = model.decode_step_gqs(cfg, p, token, pos, kv, layers)
        return (logits, new_kv)

    lowered = jax.jit(fn).lower(
        [_spec(a) for a in arrays],
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct(kv_shape, jnp.float32),
    )
    _emit(f"{family}.decode_gqs.{tag}", lowered, arrays,
          [{"name": "token", "shape": [], "dtype": "i32"},
           {"name": "pos", "shape": [], "dtype": "i32"},
           {"name": "kv", "shape": list(kv_shape), "dtype": "f32"}],
          [{"name": "logits", "shape": [cfg.vocab], "dtype": "f32"},
           {"name": "kv", "shape": list(kv_shape), "dtype": "f32"}])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--family", default="tiny-llama")
    ap.add_argument("--prefill-len", type=int, default=16)
    ap.add_argument("--t-max", type=int, default=288)
    ap.add_argument("--gqs-tag", default="w4s50g16")
    ap.add_argument("--skip-gqs", action="store_true")
    args = ap.parse_args()
    emit_prefill_dense(args.family, args.prefill_len)
    emit_decode_dense(args.family, args.t_max)
    if not args.skip_gqs:
        emit_decode_gqs(args.family, args.gqs_tag, args.t_max)


if __name__ == "__main__":
    main()
