"""Layer-1 Pallas kernel: GQS sparse-quantized GEMV / matmul.

This is the paper's GQSKernel (§3.5, Fig. 4) re-thought for TPU:

  * the CUDA version tiles the output into 1xBN tiles per CTA and stages
    weight chunks HBM->shared->registers; here each *grid step* owns a
    (BN,) output tile and BlockSpec stages the matching (BN, MG, G)
    quantized-weight tile plus per-group scale/zero/index tiles into
    VMEM (the TPU analogue of the CTA's shared-memory schedule);
  * the activation vector is small (K <= a few thousand) and lives whole
    in VMEM, so the "access the activation group by real group index"
    step (paper step 1-2) is a VMEM gather instead of a global->shared
    async copy;
  * dequantize-then-FMA (paper steps 3-4) maps to the VPU: GEMV has no
    MXU-shaped contraction, exactly as the paper's GEMV path uses
    CUDA-core FMAs rather than tensor-core MMA.

Weights arrive in the *padded-BSR* form produced by ``ref.encode`` —
``rowIndex``/``groups``/``values`` of §3.2, padded to the max group count
per row so shapes are static (padding slots carry scale 0).

Pallas runs with interpret=True: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode is the correctness path and the
TPU performance story is estimated from the BlockSpec schedule (see
DESIGN.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


DEFAULT_BN = 64  # output rows per grid step


def _gemv_kernel(x_ref, qv_ref, sc_ref, zp_ref, gi_ref, o_ref, *, group: int):
    """One grid step: compute a (BN,) output tile.

    x_ref:  (K,)        full activation vector (VMEM-resident)
    qv_ref: (BN, MG, G) quantized values (float-valued ints)
    sc_ref: (BN, MG)    scales (0.0 => padding slot)
    zp_ref: (BN, MG)    zero-points
    gi_ref: (BN, MG)    group-column indices into x
    o_ref:  (BN,)       output tile
    """
    x = x_ref[...]
    qv = qv_ref[...]
    sc = sc_ref[...]
    zp = zp_ref[...]
    gi = gi_ref[...]

    # Gather the activation groups addressed by this tile's BSR indices
    # (paper Fig. 4: "access the activation group by real group index").
    xg = x.reshape(-1, group)[gi]                       # (BN, MG, G)
    # Dequantize (Eq. 3) and fused multiply-accumulate.
    deq = (qv - zp[..., None]) * sc[..., None]          # (BN, MG, G)
    o_ref[...] = jnp.sum(deq * xg, axis=(1, 2))


def gqs_gemv(gqs: ref.GQSWeights, x: jnp.ndarray, block_n: int = DEFAULT_BN) -> jnp.ndarray:
    """Sparse-quantized GEMV: y = W_hat @ x, x: (K,) -> y: (N,)."""
    n, mg, g = gqs.qvals.shape
    assert x.shape == (gqs.k_in,), (x.shape, gqs.k_in)
    bn = min(block_n, n)
    # Pad N to a multiple of BN so the grid is exact.
    n_pad = (-n) % bn
    qv, sc, zp, gi = gqs.qvals, gqs.scales, gqs.zeros, gqs.gidx
    if n_pad:
        qv = jnp.pad(qv, ((0, n_pad), (0, 0), (0, 0)))
        sc = jnp.pad(sc, ((0, n_pad), (0, 0)))
        zp = jnp.pad(zp, ((0, n_pad), (0, 0)))
        gi = jnp.pad(gi, ((0, n_pad), (0, 0)))
    grid = ((n + n_pad) // bn,)

    out = pl.pallas_call(
        functools.partial(_gemv_kernel, group=g),
        grid=grid,
        in_specs=[
            pl.BlockSpec((gqs.k_in,), lambda i: (0,)),          # x: whole vector
            pl.BlockSpec((bn, mg, g), lambda i: (i, 0, 0)),     # qvals tile
            pl.BlockSpec((bn, mg), lambda i: (i, 0)),           # scales tile
            pl.BlockSpec((bn, mg), lambda i: (i, 0)),           # zeros tile
            pl.BlockSpec((bn, mg), lambda i: (i, 0)),           # gidx tile
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + n_pad,), jnp.float32),
        interpret=True,
    )(x, qv, sc, zp, gi)
    return out[:n]


def gqs_matmul(gqs: ref.GQSWeights, x: jnp.ndarray, block_n: int = DEFAULT_BN) -> jnp.ndarray:
    """Batched wrapper: x (..., K) -> (..., N) via vmap over the GEMV kernel."""
    lead = x.shape[:-1]
    flat = x.reshape(-1, gqs.k_in)
    f = lambda v: gqs_gemv(gqs, v, block_n=block_n)
    out = jax.vmap(f)(flat)
    return out.reshape(*lead, -1)


def vmem_estimate_bytes(n: int, k: int, mg: int, g: int, bn: int = DEFAULT_BN) -> dict:
    """Static VMEM footprint of one grid step (the §Perf L1 profile).

    On a real TPU qvals would be stored as packed int4 (g/2 bytes per
    group); interpret mode keeps them f32. Both numbers are reported.
    """
    x_bytes = k * 4
    tile_int4 = bn * mg * (g // 2 + 8)   # packed nibbles + scale/zero
    tile_f32 = bn * mg * (g * 4 + 12)
    out_bytes = bn * 4
    return {
        "x_bytes": x_bytes,
        "weight_tile_bytes_tpu_int4": tile_int4,
        "weight_tile_bytes_interp_f32": tile_f32,
        "out_bytes": out_bytes,
        "total_tpu": x_bytes + tile_int4 + out_bytes,
        "total_interp": x_bytes + tile_f32 + out_bytes,
    }
