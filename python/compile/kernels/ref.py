"""Pure-jnp oracle for the GQS (group-quantized-sparse) layer.

This module is the *reference semantics* for everything the system does
with GQS weights:

  * per-group asymmetric uniform quantization (paper Eq. 1-3),
  * 1xG group pruning along the row (input) dimension (paper §3.2),
  * the padded-BSR representation shared with the Pallas kernel and the
    Rust engine,
  * a dense-reconstruction GEMV/matmul oracle the kernel is tested
    against (pytest + hypothesis).

Convention: a linear layer weight has shape (N, K) = (out_features,
in_features); groups are G consecutive *input* channels of one output
row ("1xN sparse mode" in the paper's words, §Appendix I).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Group quantization (Eq. 1-3)
# ---------------------------------------------------------------------------

def quant_params(w_groups: jnp.ndarray, bits: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-group (scale, zero) for asymmetric uniform quantization.

    w_groups: (..., G). Returns scale (...,), zero (...,) with the paper's
    convention  s = (max-min)/(2^n - 1),  z = -floor(min/s).
    """
    qmax = 2.0**bits - 1.0
    wmax = jnp.max(w_groups, axis=-1)
    wmin = jnp.min(w_groups, axis=-1)
    scale = (wmax - wmin) / qmax
    scale = jnp.where(scale <= 1e-12, 1e-12, scale)
    zero = -jnp.floor(wmin / scale)
    zero = jnp.clip(zero, 0.0, qmax)
    # Constant-group edge case (matches rust quant::group): literal Eq. 1
    # collapses the scale and decodes the group to 0; pick (s, z) that
    # reproduce the constant exactly instead.
    const = (wmax - wmin) <= 1e-12 * jnp.maximum(jnp.abs(wmax), 1.0)
    nonzero_const = const & (jnp.abs(wmax) > 0)
    scale = jnp.where(nonzero_const, jnp.abs(wmax), scale)
    zero = jnp.where(nonzero_const, jnp.where(wmax >= 0, 0.0, qmax), zero)
    return scale, zero


def quantize(w_groups: jnp.ndarray, scale: jnp.ndarray, zero: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Eq. 2: q = clamp(round(w/s) + z, 0, 2^n-1). Returns float-valued ints."""
    qmax = 2.0**bits - 1.0
    q = jnp.round(w_groups / scale[..., None]) + zero[..., None]
    return jnp.clip(q, 0.0, qmax)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray, zero: jnp.ndarray) -> jnp.ndarray:
    """Eq. 3: w_hat = (q - z) * s."""
    return (q - zero[..., None]) * scale[..., None]


def quant_dequant(w_groups: jnp.ndarray, bits: int) -> jnp.ndarray:
    scale, zero = quant_params(w_groups, bits)
    return dequantize(quantize(w_groups, scale, zero, bits), scale, zero)


# ---------------------------------------------------------------------------
# Group pruning + padded-BSR encoding
# ---------------------------------------------------------------------------

class GQSWeights(NamedTuple):
    """Padded-BSR GQS layer (the representation the Pallas kernel consumes).

    qvals:  (N, MG, G) float-valued ints in [0, 2^bits)
    scales: (N, MG)    f32, 0.0 on padding slots
    zeros:  (N, MG)    f32
    gidx:   (N, MG)    i32 group-column index (0 on padding slots)
    mask:   (N, K//G)  original keep-mask (bool), for accounting/tests
    bits:   int
    group:  int        G
    k_in:   int        K
    """

    qvals: jnp.ndarray
    scales: jnp.ndarray
    zeros: jnp.ndarray
    gidx: jnp.ndarray
    mask: jnp.ndarray
    bits: int
    group: int
    k_in: int


def group_mask_from_scores(scores: np.ndarray, sparsity: float) -> np.ndarray:
    """Keep-mask (N, NG) keeping the top-(1-sparsity) groups *per row*.

    Per-row selection mirrors the BSR layout (each row owns its surviving
    groups) and keeps every output channel alive.
    """
    n, ng = scores.shape
    keep = max(1, int(round(ng * (1.0 - sparsity))))
    order = np.argsort(-scores, axis=1, kind="stable")
    mask = np.zeros((n, ng), dtype=bool)
    np.put_along_axis(mask, order[:, :keep], True, axis=1)
    return mask


def encode(w: np.ndarray, mask: np.ndarray, bits: int, group: int) -> GQSWeights:
    """Dense (N,K) + keep-mask (N, K//G) -> padded-BSR GQS weights."""
    w = np.asarray(w, dtype=np.float32)
    mask = np.asarray(mask, dtype=bool)
    n, k = w.shape
    ng = k // group
    assert ng * group == k, f"K={k} not divisible by G={group}"
    assert mask.shape == (n, ng)
    wg = w.reshape(n, ng, group)

    counts = mask.sum(axis=1)
    mg = int(counts.max()) if n else 0
    mg = max(mg, 1)

    qvals = np.zeros((n, mg, group), dtype=np.float32)
    scales = np.zeros((n, mg), dtype=np.float32)
    zeros = np.zeros((n, mg), dtype=np.float32)
    gidx = np.zeros((n, mg), dtype=np.int32)
    for i in range(n):
        cols = np.nonzero(mask[i])[0]
        if len(cols) == 0:
            continue
        g = jnp.asarray(wg[i, cols])
        s, z = quant_params(g, bits)
        q = quantize(g, s, z, bits)
        qvals[i, : len(cols)] = np.asarray(q)
        scales[i, : len(cols)] = np.asarray(s)
        zeros[i, : len(cols)] = np.asarray(z)
        gidx[i, : len(cols)] = cols
    return GQSWeights(
        jnp.asarray(qvals), jnp.asarray(scales), jnp.asarray(zeros),
        jnp.asarray(gidx), jnp.asarray(mask), bits, group, k,
    )


def decode_dense(gqs: GQSWeights) -> jnp.ndarray:
    """Reconstruct the dense (N, K) de-quantized weight (oracle)."""
    n, mg, g = gqs.qvals.shape
    live = (gqs.scales[..., None] != 0.0)
    deq = (gqs.qvals - gqs.zeros[..., None]) * gqs.scales[..., None]   # (N,MG,G)
    ng = gqs.k_in // g
    w = jnp.zeros((n, ng, g), dtype=jnp.float32)
    rows = jnp.repeat(jnp.arange(n)[:, None], mg, axis=1)
    w = w.at[rows, gqs.gidx].add(jnp.where(live, deq, 0.0))
    return w.reshape(n, gqs.k_in)


# ---------------------------------------------------------------------------
# Oracles the Pallas kernel is tested against
# ---------------------------------------------------------------------------

def gqs_gemv_ref(gqs: GQSWeights, x: jnp.ndarray) -> jnp.ndarray:
    """y = W_hat @ x via dense reconstruction. x: (K,) -> (N,)."""
    return decode_dense(gqs) @ x


def gqs_gemv_gather_ref(gqs: GQSWeights, x: jnp.ndarray) -> jnp.ndarray:
    """Same result computed the way the kernel does (gather, no dense W)."""
    g = gqs.group
    xg = x.reshape(-1, g)[gqs.gidx]                        # (N, MG, G)
    deq = (gqs.qvals - gqs.zeros[..., None]) * gqs.scales[..., None]
    return jnp.sum(deq * xg, axis=(1, 2))


def gqs_matmul_ref(gqs: GQSWeights, x: jnp.ndarray) -> jnp.ndarray:
    """Batched oracle: x (..., K) -> (..., N)."""
    return x @ decode_dense(gqs).T
