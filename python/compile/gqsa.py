"""The GQSA compression pipeline (paper §3): calibration -> group pruning
-> group quantization -> BQPO -> E2E-OQP -> BSR export.

Stages
------
1. **Hessian calibration** — run the FP model over calibration text and
   accumulate per-linear-layer input Hessians  H = Σ XᵀX  (the GPTQ /
   SparseGPT H). Saliency is Eq. 4:  s_i = w_i² / [H⁻¹]_ii².
2. **Group pruning** (§3.2) — scores averaged over 1xG groups along the
   input dim; per-row top-k groups survive ("1xN sparse mode").
3. **BQPO** (§3.3) — block-wise: optimize each block's *surviving
   weights* (STE through quant-dequant) to match the FP block's outputs.
4. **E2E-OQP** (§3.4) — freeze the integer codes, train only per-group
   (scale, zero) end-to-end against the FP model's logits.
5. **Export** — Block-Sparse-Row container (`rowIndex`/`groups`/packed
   nibble `values` + scales/zeros), the exact storage structure of §3.2,
   read by the Rust engine (`rust/src/gqs/format.rs`).

All jitted steps are shape-stable across sparsity levels (full-NG frozen
tensors with masks), so a whole sweep pays XLA compilation once per
family.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import common, model
from .common import ART, FAMILIES, ModelConfig, StageTimer
from .kernels import ref


# ---------------------------------------------------------------------------
# Calibration
# ---------------------------------------------------------------------------

def calib_batches(corpus: np.ndarray, n_seq: int, ctx: int, seed: int = 7) -> np.ndarray:
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, len(corpus) - ctx - 1, size=n_seq)
    return np.stack([corpus[i : i + ctx] for i in idx]).astype(np.int32)


def calibrate(cfg: ModelConfig, p: dict, seqs: np.ndarray):
    """Returns (hessians {lname: (K,K)}, block_inputs {i: (B,T,D)}, fp_logits (B,T,V))."""
    lnames = model.linear_names(cfg)
    fwd = jax.jit(lambda tk: model.forward_capture(cfg, p, tk))
    hess = {n: None for n in lnames}
    blk_in = {i: [] for i in range(cfg.n_layers)}
    logits_all = []
    for s in seqs:
        logits, caps = fwd(jnp.asarray(s))
        logits_all.append(np.asarray(logits))
        for n in lnames:
            x = caps[n]  # (T, K)
            h = np.asarray(x.T @ x, dtype=np.float64)
            hess[n] = h if hess[n] is None else hess[n] + h
        for i in range(cfg.n_layers):
            blk_in[i].append(np.asarray(caps[f"blk{i}.__in__"]))
    blk_in = {i: np.stack(v) for i, v in blk_in.items()}
    return hess, blk_in, np.stack(logits_all)


def hinv_diag(h: np.ndarray, damp: float = 0.01) -> np.ndarray:
    """Damped inverse-Hessian diagonal (the [H⁻¹]_ii of Eq. 4)."""
    k = h.shape[0]
    d = damp * float(np.mean(np.diag(h))) + 1e-8
    hd = h + d * np.eye(k)
    try:
        hinv = np.linalg.inv(hd)
    except np.linalg.LinAlgError:
        hinv = np.linalg.pinv(hd)
    return np.clip(np.diag(hinv), 1e-12, None)


def saliency(w: np.ndarray, hinv_d: np.ndarray, group: int) -> np.ndarray:
    """Group saliency (N, K//G): mean over the group of  w² / [H⁻¹]_ii²."""
    s = (w.astype(np.float64) ** 2) / (hinv_d[None, :] ** 2)
    n, k = w.shape
    return s.reshape(n, k // group, group).mean(axis=2).astype(np.float64)


def build_masks(cfg: ModelConfig, p: dict, hess: dict, sparsity: float, group: int) -> dict:
    masks = {}
    for n in model.linear_names(cfg):
        hd = hinv_diag(hess[n])
        sc = saliency(np.asarray(p[n]), hd, group)
        masks[n] = ref.group_mask_from_scores(sc, sparsity)
    return masks


# ---------------------------------------------------------------------------
# Stage 1: BQPO — block-wise quantization-pruning optimization
# ---------------------------------------------------------------------------

def _strip_block(cfg: ModelConfig, p: dict, i: int) -> dict:
    """Extract block i's params, renamed to blk0.* so one jit fits all blocks."""
    out = {}
    pre, pre0 = f"blk{i}.", "blk0."
    for k, v in p.items():
        if k.startswith(pre):
            out[pre0 + k[len(pre):]] = v
    return out


def bqpo(cfg: ModelConfig, p: dict, masks: dict, bits: int, group: int,
         blk_in: dict, steps: int = 40, lr: float = 1e-4, log=None) -> dict:
    """Optimize surviving weights per block (STE quant) to match FP outputs."""
    lsuffixes = [n.split(".", 1)[1] for n in model.linear_names(cfg) if n.startswith("blk0.")]

    def loss_fn(trainable, static_bp, masks0, x):
        bp = dict(static_bp)
        bp.update(trainable)
        wm = model.wmap_qdq_ste(cfg, bp, masks0, bits, group)
        y = model.block_apply(cfg, bp, wm, 0, x)
        # FP target computed inside: same block, identity wmap, FP weights.
        return y

    @jax.jit
    def step(trainable, opt_m, opt_v, t, static_bp, masks0, x, y_fp):
        def mse(tr):
            y = loss_fn(tr, static_bp, masks0, x)
            return jnp.mean((y - y_fp) ** 2)
        l, g = jax.value_and_grad(mse)(trainable)
        new_tr, new_m, new_v = {}, {}, {}
        for k in trainable:
            m = 0.9 * opt_m[k] + 0.1 * g[k]
            v = 0.95 * opt_v[k] + 0.05 * g[k] ** 2
            mh = m / (1 - 0.9**t)
            vh = v / (1 - 0.95**t)
            new_tr[k] = trainable[k] - lr * mh / (jnp.sqrt(vh) + 1e-8)
            new_m[k], new_v[k] = m, v
        return new_tr, new_m, new_v, l

    @jax.jit
    def fp_block(bp, x):
        return model.block_apply(cfg, bp, lambda n: bp[n], 0, x)

    new_p = dict(p)
    for i in range(cfg.n_layers):
        bp = {k: jnp.asarray(v) for k, v in _strip_block(cfg, p, i).items()}
        masks0 = {f"blk0.{sfx}": masks[f"blk{i}.{sfx}"] for sfx in lsuffixes}
        x = jnp.asarray(blk_in[i])
        y_fp = fp_block(bp, x)
        trainable = {k: bp[k] for k in masks0}
        static_bp = {k: v for k, v in bp.items() if k not in masks0}
        opt_m = {k: jnp.zeros_like(v) for k, v in trainable.items()}
        opt_v = {k: jnp.zeros_like(v) for k, v in trainable.items()}
        l0 = None
        for t in range(1, steps + 1):
            trainable, opt_m, opt_v, l = step(trainable, opt_m, opt_v, float(t), static_bp, masks0, x, y_fp)
            if l0 is None:
                l0 = float(l)
        if log is not None:
            log.append({"block": i, "loss_first": l0, "loss_last": float(l)})
        for k, v in trainable.items():
            new_p[f"blk{i}." + k[len("blk0."):]] = np.asarray(v)
    return new_p


# ---------------------------------------------------------------------------
# Stage 2: E2E-OQP — freeze integer codes, tune (scale, zero) end-to-end
# ---------------------------------------------------------------------------

def freeze_quantize(cfg: ModelConfig, p: dict, masks: dict, bits: int, group: int):
    """Integer codes + initial (s, z) for every GQS layer (full NG, mask kept)."""
    frozen, sz = {}, {}
    for n in model.linear_names(cfg):
        w = jnp.asarray(p[n])
        nrows, k = w.shape
        wg = w.reshape(nrows, k // group, group)
        s, z = ref.quant_params(wg, bits)
        q = ref.quantize(wg, s, z, bits)
        frozen[n] = (q, jnp.asarray(masks[n]))
        sz[n] = {"s": s, "z": z}
    return frozen, sz


def e2e_oqp(cfg: ModelConfig, p: dict, frozen: dict, sz: dict, group: int,
            seqs: np.ndarray, fp_logits: np.ndarray, steps: int = 40,
            lr: float = 1e-4, batch: int = 4, log=None) -> dict:
    """Distill FP logits into the frozen-integer model through (s, z) only."""
    pj = {k: jnp.asarray(v) for k, v in p.items()}

    def loss_fn(sz_tr, toks, y_fp):
        wm = model.wmap_frozen_q(cfg, pj, frozen, sz_tr, group)
        logits = model.forward_batch(cfg, pj, toks, wm)
        return jnp.mean((logits - y_fp) ** 2)

    @jax.jit
    def step(sz_tr, opt_m, opt_v, t, toks, y_fp):
        l, g = jax.value_and_grad(loss_fn)(sz_tr, toks, y_fp)
        new_sz, new_m, new_v = {}, {}, {}
        for n in sz_tr:
            new_sz[n], new_m[n], new_v[n] = {}, {}, {}
            for c in ("s", "z"):
                m = 0.9 * opt_m[n][c] + 0.1 * g[n][c]
                v = 0.95 * opt_v[n][c] + 0.05 * g[n][c] ** 2
                mh = m / (1 - 0.9**t)
                vh = v / (1 - 0.95**t)
                new_sz[n][c] = sz_tr[n][c] - lr * mh / (jnp.sqrt(vh) + 1e-8)
                new_m[n][c], new_v[n][c] = m, v
        return new_sz, new_m, new_v, l

    zeros_like = lambda tree: {n: {c: jnp.zeros_like(tree[n][c]) for c in ("s", "z")} for n in tree}
    opt_m, opt_v = zeros_like(sz), zeros_like(sz)
    n_seq = seqs.shape[0]
    rng = np.random.default_rng(3)
    l0 = None
    for t in range(1, steps + 1):
        idx = rng.integers(0, n_seq, size=batch)
        toks = jnp.asarray(seqs[idx])
        y_fp = jnp.asarray(fp_logits[idx])
        sz, opt_m, opt_v, l = step(sz, opt_m, opt_v, float(t), toks, y_fp)
        if l0 is None:
            l0 = float(l)
    if log is not None:
        log.append({"e2e_loss_first": l0, "e2e_loss_last": float(l)})
    return sz


# ---------------------------------------------------------------------------
# Export: BSR container (§3.2 storage structure)
# ---------------------------------------------------------------------------

def pack_nibbles(q: np.ndarray, bits: int) -> np.ndarray:
    """Pack integer codes into bytes. q: flat uint8 array of codes."""
    q = q.astype(np.uint8)
    if bits == 8:
        return q
    if bits == 4:
        if len(q) % 2:
            q = np.concatenate([q, np.zeros(1, np.uint8)])
        return (q[0::2] | (q[1::2] << 4)).astype(np.uint8)
    if bits == 2:
        pad = (-len(q)) % 4
        if pad:
            q = np.concatenate([q, np.zeros(pad, np.uint8)])
        return (q[0::4] | (q[1::4] << 2) | (q[2::4] << 4) | (q[3::4] << 6)).astype(np.uint8)
    raise ValueError(f"bits={bits}")


def export_gqsa(path, cfg: ModelConfig, p: dict, frozen: dict, sz: dict,
                masks: dict, bits: int, group: int, sparsity: float,
                extra_meta: dict | None = None) -> dict:
    """Write the .gqsa container; returns byte-accounting stats."""
    tensors: dict[str, np.ndarray] = {}
    lnames = model.linear_names(cfg)
    stats = {"gqs_bytes": 0, "dense_bytes": 0, "fp_bytes": 0}
    for n, v in p.items():
        if n not in lnames:
            tensors[n] = np.asarray(v, dtype=np.float32)
            stats["dense_bytes"] += tensors[n].nbytes
    for n in lnames:
        q_full, _ = frozen[n]
        s_full, z_full = np.asarray(sz[n]["s"]), np.asarray(sz[n]["z"])
        mask = np.asarray(masks[n], dtype=bool)
        nrows, ng = mask.shape
        row_ptr = np.zeros(nrows + 1, dtype=np.int32)
        cols_all, q_codes, s_out, z_out = [], [], [], []
        qmax = 2**bits - 1
        q_np = np.asarray(q_full)
        for r in range(nrows):
            cols = np.nonzero(mask[r])[0]
            row_ptr[r + 1] = row_ptr[r] + len(cols)
            cols_all.append(cols.astype(np.int32))
            q_codes.append(q_np[r, cols].reshape(-1))
            s_out.append(s_full[r, cols])
            # zero-points are integers by construction; round defensively
            z_out.append(np.clip(np.round(z_full[r, cols]), 0, qmax))
        cols_all = np.concatenate(cols_all) if cols_all else np.zeros(0, np.int32)
        codes = np.clip(np.round(np.concatenate(q_codes)), 0, qmax).astype(np.uint8) if q_codes else np.zeros(0, np.uint8)
        tensors[n + ".row_ptr"] = row_ptr
        tensors[n + ".cols"] = cols_all
        tensors[n + ".qvals"] = pack_nibbles(codes, bits)
        tensors[n + ".scales"] = np.concatenate(s_out).astype(np.float32)
        tensors[n + ".zeros"] = np.concatenate(z_out).astype(np.uint8)
        stats["gqs_bytes"] += sum(tensors[n + sfx].nbytes for sfx in (".row_ptr", ".cols", ".qvals", ".scales", ".zeros"))
        stats["fp_bytes"] += np.asarray(p[n]).nbytes
    meta = {
        "kind": "gqsa",
        "config": cfg.to_json(),
        "bits": bits,
        "group": group,
        "sparsity": sparsity,
        "gqs_layers": lnames,
        "stats": stats,
    }
    if extra_meta:
        meta.update(extra_meta)
    common.save_tensors(path, tensors, meta=meta)
    return stats


# ---------------------------------------------------------------------------
# Full pipeline
# ---------------------------------------------------------------------------

def compress(family: str, sparsity: float, bits: int = 4, group: int = 16,
             bqpo_steps: int = 40, e2e_steps: int = 40, n_calib: int = 16,
             ctx: int = 192, tag: str | None = None,
             _cache: dict | None = None) -> dict:
    """Run the full GQSA pipeline for one (family, sparsity, G, bits) setting.

    ``_cache`` lets sweep drivers reuse the expensive FP calibration pass
    across settings of the same family.
    """
    cfg = FAMILIES[family]
    tensors, meta = common.load_tensors(ART / "models" / f"{family}.fp.bin")
    p = {k: v for k, v in tensors.items()}
    corpus = np.frombuffer((ART / "corpus" / "train.bin").read_bytes(), dtype=np.uint8)

    timer = StageTimer()
    log: list = []
    if _cache is not None and "calib" in _cache:
        hess, blk_in, fp_logits, seqs = _cache["calib"]
    else:
        seqs = calib_batches(corpus, n_calib, ctx)
        with timer.stage("calibrate"):
            hess, blk_in, fp_logits = calibrate(cfg, {k: jnp.asarray(v) for k, v in p.items()}, seqs)
        if _cache is not None:
            _cache["calib"] = (hess, blk_in, fp_logits, seqs)

    with timer.stage("masks"):
        masks = build_masks(cfg, p, hess, sparsity, group)

    with timer.stage("bqpo"):
        p_bqpo = bqpo(cfg, p, masks, bits, group, blk_in, steps=bqpo_steps, log=log) \
            if bqpo_steps > 0 else dict(p)

    with timer.stage("freeze"):
        frozen, sz = freeze_quantize(cfg, p_bqpo, masks, bits, group)

    with timer.stage("e2e_oqp"):
        if e2e_steps > 0:
            sz = e2e_oqp(cfg, p_bqpo, frozen, sz, group, seqs, fp_logits, steps=e2e_steps, log=log)

    tag = tag or f"w{bits}s{int(sparsity*100)}g{group}"
    out = ART / "models" / f"{family}.{tag}.gqsa"
    stats = export_gqsa(out, cfg, p_bqpo, frozen, sz, masks, bits, group, sparsity,
                        extra_meta={"tag": tag, "opt_log": log,
                                    "bqpo_steps": bqpo_steps, "e2e_steps": e2e_steps})
    timer.dump(ART / "logs" / f"compress.{family}.{tag}.json")
    total = stats["gqs_bytes"] + stats["dense_bytes"]
    print(f"[{family}/{tag}] gqs={stats['gqs_bytes']} dense={stats['dense_bytes']} "
          f"(fp linear {stats['fp_bytes']}) ratio={stats['fp_bytes']/max(stats['gqs_bytes'],1):.2f}x -> {out}")
    return {"path": str(out), "stats": stats}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--family", default="tiny-llama")
    ap.add_argument("--sparsity", type=float, nargs="*", default=[0.5])
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--group", type=int, nargs="*", default=[16])
    ap.add_argument("--bqpo-steps", type=int, default=40)
    ap.add_argument("--e2e-steps", type=int, default=40)
    ap.add_argument("--tag", default=None)
    args = ap.parse_args()
    cache: dict = {}
    for s in args.sparsity:
        for g in args.group:
            t0 = time.time()
            compress(args.family, s, bits=args.bits, group=g,
                     bqpo_steps=args.bqpo_steps, e2e_steps=args.e2e_steps,
                     tag=args.tag, _cache=cache)
            print(f"  ({time.time()-t0:.1f}s)")


if __name__ == "__main__":
    main()
