import sys
from pathlib import Path

# allow `pytest python/tests/` from the repo root
sys.path.insert(0, str(Path(__file__).resolve().parent))
